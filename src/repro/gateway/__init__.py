"""The serving gateway: a replica pool, an (ε, δ)-aware result cache, and
a metrics/health layer above the :class:`~repro.service.FrogWildService`
facade.

FrogWild's Theorem-1 certificates make result reuse *principled* rather
than heuristic. The tier's one invariant — the **dominance contract** —
is:

    a cached (or in-flight) answer certified at (ε′, δ′) may serve a
    request for (ε, δ) **iff ε′ ≤ ε and δ′ ≤ δ** — the stored guarantee
    is at least as strong in both coordinates, so the caller receives
    exactly the accuracy they asked for (or better) with zero new walks.

Three layers enforce it:

* :class:`~repro.gateway.pool.ReplicaPool` — N service replicas sharing
  ONE graph + walk-index slab (no N-fold duplication), routed by
  EDF-charged queue depth from each scheduler's admission accounting.
* :class:`~repro.gateway.cache.ResultCache` — a Pareto frontier of
  certificates per (kind, k, source, graph-epoch) key; degraded answers
  are never cached; epoch bumps orphan stale keys.
* :class:`~repro.gateway.gateway.Gateway` — the submit path (cache →
  in-flight join → replica), with :class:`~repro.gateway.metrics.
  GatewayMetrics` and the stdlib HTTP front-end
  (:func:`~repro.gateway.http.serve_http`: ``/pagerank`` ``/topk``
  ``/ppr`` ``/healthz`` ``/metrics``).

The gateway-tier degradation contract (PR 8)
--------------------------------------------

The pool is *supervised*, and the tier degrades in defined steps instead
of hanging or lying:

* **Supervision.** All wave driving goes through the pool's
  ``step_replica``: per-replica circuit breakers (``closed`` → ``open``
  on crash / missed heartbeat / repeated wave failures → ``half_open``
  after the cooldown → ``closed`` on a clean probe wave) quarantine sick
  replicas out of ``route()``; health scores in [0, 1] fold consecutive
  failures and a wave-time EMA straggler term.
* **Failover byte-identity.** A query whose replica dies mid-flight is
  *replayed* on a healthy replica with the same plan parameters. Every
  replica is seeded identically and a fresh replica's key stream starts
  at wave 0, so failover onto a cold (or freshly restarted) replica
  returns an answer **byte-identical** to the fault-free run. Joined
  handles migrate with their parent, or settle with a classified
  ``WaveFailedError`` — never a hang.
* **Restart.** A crashed replica is re-opened over the *same* shared
  slab (object identity asserted, zero index rebuild) and re-enters
  rotation through the half-open probe.
* **Shedding.** Overload (backlog past the shed threshold, all breakers
  open, or draining) raises :class:`~repro.gateway.gateway.
  GatewayOverloadError` carrying ``retry_after_s`` — HTTP 503 +
  ``Retry-After`` — instead of queueing callers into a lock convoy.
* **Drain.** ``Gateway.drain()`` stops admitting (new submits shed with
  ``reason="draining"``), drives every in-flight handle to completion
  through the supervised path, then closes the pool.
* **Epoch safety.** A certificate earned under graph epoch *e* is
  refused by the cache once the gateway moved to *e+1* (the
  ``min_epoch`` guard) — a ``bump_epoch()`` racing an in-flight query
  can never resurrect a stale answer.

Quickstart::

    from repro.gateway import Gateway, serve_http

    with Gateway.open("graph.npz", replicas=2) as gw:
        r1 = gw.topk(k=10, epsilon=0.2, delta=0.1).result()
        r2 = gw.topk(k=10, epsilon=0.3, delta=0.1).result()  # cache hit:
        server = serve_http(gw)          # zero walks, dominated certificate
        print(server.url, gw.stats()["hit_rate"])
        server.close()
"""
from repro.gateway.cache import CacheEntry, Certificate, ResultCache
from repro.gateway.gateway import (Gateway, GatewayHandle,
                                   GatewayOverloadError)
from repro.gateway.http import GatewayHTTPServer, serve_http
from repro.gateway.metrics import GatewayMetrics
from repro.gateway.pool import NoReplicaAvailable, ReplicaPool

__all__ = [
    "CacheEntry",
    "Certificate",
    "Gateway",
    "GatewayHTTPServer",
    "GatewayHandle",
    "GatewayMetrics",
    "GatewayOverloadError",
    "NoReplicaAvailable",
    "ReplicaPool",
    "ResultCache",
    "serve_http",
]
