"""The serving gateway: a replica pool, an (ε, δ)-aware result cache, and
a metrics/health layer above the :class:`~repro.service.FrogWildService`
facade.

FrogWild's Theorem-1 certificates make result reuse *principled* rather
than heuristic. The tier's one invariant — the **dominance contract** —
is:

    a cached (or in-flight) answer certified at (ε′, δ′) may serve a
    request for (ε, δ) **iff ε′ ≤ ε and δ′ ≤ δ** — the stored guarantee
    is at least as strong in both coordinates, so the caller receives
    exactly the accuracy they asked for (or better) with zero new walks.

Three layers enforce it:

* :class:`~repro.gateway.pool.ReplicaPool` — N service replicas sharing
  ONE graph + walk-index slab (no N-fold duplication), routed by
  EDF-charged queue depth from each scheduler's admission accounting.
* :class:`~repro.gateway.cache.ResultCache` — a Pareto frontier of
  certificates per (kind, k, source, graph-epoch) key; degraded answers
  are never cached; epoch bumps orphan stale keys.
* :class:`~repro.gateway.gateway.Gateway` — the submit path (cache →
  in-flight join → replica), with :class:`~repro.gateway.metrics.
  GatewayMetrics` and the stdlib HTTP front-end
  (:func:`~repro.gateway.http.serve_http`: ``/pagerank`` ``/topk``
  ``/ppr`` ``/healthz`` ``/metrics``).

Quickstart::

    from repro.gateway import Gateway, serve_http

    with Gateway.open("graph.npz", replicas=2) as gw:
        r1 = gw.topk(k=10, epsilon=0.2, delta=0.1).result()
        r2 = gw.topk(k=10, epsilon=0.3, delta=0.1).result()  # cache hit:
        server = serve_http(gw)          # zero walks, dominated certificate
        print(server.url, gw.stats()["hit_rate"])
        server.close()
"""
from repro.gateway.cache import CacheEntry, Certificate, ResultCache
from repro.gateway.gateway import Gateway, GatewayHandle
from repro.gateway.http import GatewayHTTPServer, serve_http
from repro.gateway.metrics import GatewayMetrics
from repro.gateway.pool import ReplicaPool

__all__ = [
    "CacheEntry",
    "Certificate",
    "Gateway",
    "GatewayHTTPServer",
    "GatewayHandle",
    "GatewayMetrics",
    "ReplicaPool",
    "ResultCache",
    "serve_http",
]
