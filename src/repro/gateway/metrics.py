"""Gateway metrics: counters, latency quantiles, and qps over a sliding
window — the numbers a load balancer or dashboard needs to know whether
the tier is healthy, aggregated from the gateway's own accounting plus
each replica scheduler's :class:`~repro.query.scheduler.SchedulerStats`.

Everything is plain host state (no device work): ``snapshot()`` returns a
JSON-ready dict and is what ``/metrics`` serves.

Fault-tolerance counters (PR 8): ``failovers`` (queries migrated off a
crashed/stalled replica and replayed elsewhere), ``hedges_fired`` /
``hedges_won`` (duplicate submissions raced against a slow primary, and
how often the hedge certified first), ``sheds`` (submits refused with a
structured overload error — 503 + Retry-After at the HTTP layer — instead
of queueing into a lock convoy), and ``timeouts`` (request deadlines that
expired, HTTP 504). Per-replica health scores, breaker states, and
restart counts live in ``Gateway.stats()["replicas"]`` since they are
supervision state, not counters.
"""
from __future__ import annotations

import collections
import time
from typing import Deque, Dict, Optional, Tuple

import numpy as np

from repro.query.scheduler import AdmissionDecision, RejectReason

__all__ = ["GatewayMetrics"]

# completions remembered for the latency/qps window — enough for stable
# p99 at serving rates, small enough to never matter for memory.
_WINDOW = 2048


class GatewayMetrics:
    def __init__(self):
        self.requests = 0            # everything submitted through the tier
        self.completed = 0           # results handed back (any source)
        self.cache_hits = 0          # served straight from the result cache
        self.joins = 0               # attached to an in-flight duplicate
        self.live = 0                # routed to a replica as a new query
        self.rejected = 0            # replica admission refused
        self.downgraded = 0          # admitted with a clamped plan
        self.rejects_by_reason: Dict[str, int] = collections.Counter()
        # --- fault-tolerance counters (PR 8) ---
        self.failovers = 0           # queries migrated off a dead replica
        self.hedges_fired = 0        # hedged duplicate submissions
        self.hedges_won = 0          # … where the hedge certified first
        self.sheds = 0               # submits refused by overload/breakers
        self.timeouts = 0            # request deadlines expired (HTTP 504)
        # --- dynamic-graph counter (PR 10) ---
        self.epoch_orphaned = 0      # cached certificates dropped by epoch
                                     # bumps (mutation commits)
        # (t_done, latency_s) pairs, newest last
        self._window: Deque[Tuple[float, float]] = collections.deque(
            maxlen=_WINDOW)

    # --- recording hooks (called by the gateway) -------------------------

    def record_admission(self, decision: AdmissionDecision) -> None:
        if not decision.admitted:
            self.rejected += 1
            code = decision.reason_code
            self.rejects_by_reason[
                code.value if isinstance(code, RejectReason) else str(code)
            ] += 1
        elif decision.downgraded:
            self.downgraded += 1

    def record_completion(self, latency_s: float) -> None:
        self.completed += 1
        self._window.append((time.monotonic(), float(latency_s)))

    # --- snapshot ---------------------------------------------------------

    def qps(self) -> float:
        """Completions/sec over the sliding window (0 before 2 samples)."""
        if len(self._window) < 2:
            return 0.0
        span = self._window[-1][0] - self._window[0][0]
        return (len(self._window) - 1) / span if span > 0 else 0.0

    def latency_percentiles(self) -> Tuple[Optional[float], Optional[float]]:
        if not self._window:
            return None, None
        lat = np.asarray([l for _, l in self._window])
        return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))

    def snapshot(self) -> Dict[str, object]:
        p50, p99 = self.latency_percentiles()
        return {
            "requests": self.requests,
            "completed": self.completed,
            "cache_hits": self.cache_hits,
            "joins": self.joins,
            "live": self.live,
            "rejected": self.rejected,
            "downgraded": self.downgraded,
            "rejects_by_reason": dict(self.rejects_by_reason),
            "failovers": self.failovers,
            "hedges_fired": self.hedges_fired,
            "hedges_won": self.hedges_won,
            "sheds": self.sheds,
            "timeouts": self.timeouts,
            "epoch_orphaned": self.epoch_orphaned,
            "hit_rate": (self.cache_hits / self.requests
                         if self.requests else 0.0),
            "join_rate": (self.joins / self.requests
                          if self.requests else 0.0),
            "qps": round(self.qps(), 3),
            "p50_ms": None if p50 is None else round(p50 * 1e3, 3),
            "p99_ms": None if p99 is None else round(p99 * 1e3, 3),
        }
