"""Fault-tolerance substrate: atomic, checksummed, async checkpoints +
elastic restore."""
from repro.checkpoint.checkpointer import (
    CheckpointCorruptError,
    Checkpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointCorruptError",
    "Checkpointer",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
]
