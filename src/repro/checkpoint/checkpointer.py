"""Checkpointing: atomic, async, sharding-aware, elastic.

Layout: ``<dir>/step_<k>/``
  * ``tree.json``     — pytree structure + per-leaf dtype/shape + pspec
  * ``arrays.npz``    — leaf data, keyed by flattened index

Fault-tolerance properties:
  * **atomic** — written to ``step_<k>.tmp``, fsynced, then os.rename'd:
    a crash mid-write never leaves a half-written ``step_<k>/`` visible to
    ``latest_step`` (the ``.tmp`` / ``.old`` suffixes are filtered);
  * **verified** — ``tree.json`` carries a per-leaf crc32 manifest;
    ``restore_checkpoint`` recomputes every leaf's checksum and raises
    :class:`CheckpointCorruptError` (naming the step dir and leaf) on a
    corrupt or truncated payload instead of silently consuming it;
  * **async**  — ``Checkpointer.save_async`` snapshots to host memory
    synchronously (cheap) and writes on a background thread, so the train
    loop is blocked only for the device→host copy; a background-write
    failure is re-raised at the next ``wait()`` / ``save_async()`` rather
    than vanishing with the thread;
  * **elastic** — restore takes the *target* mesh + spec tree and
    ``jax.device_put``s each leaf with the new sharding: a checkpoint
    written on N chips restores onto M ≠ N chips (scale up/down without
    retraining) — see distributed/elastic.py for the mesh-shape change
    helper and tests/test_checkpoint.py for the roundtrip proof.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A checkpoint payload failed integrity verification (bad checksum,
    truncated archive, missing member/metadata). The message names the
    offending step dir so callers can quarantine and rebuild it."""


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _fsync_path(path: str) -> None:
    """fsyncs a file or directory so the atomic rename publishes durable
    bytes, not page-cache promises."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the final checkpoint path.

    Write protocol: payload + manifest land in ``step_<k>.tmp``, both
    files and the tmp dir are fsynced, and only then is the dir renamed to
    ``step_<k>`` (and the parent fsynced) — a crash at any point leaves
    either the previous complete checkpoint or a ``.tmp``/``.old`` dir
    that ``latest_step`` ignores, never a torn ``step_<k>/``.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    paths, leaves, treedef = _flatten_with_paths(tree)
    # npz cannot round-trip extended dtypes (bf16 → void); store raw bytes
    # and reconstruct from the recorded dtype/shape on restore.
    arrays = {
        f"a{i}": np.ascontiguousarray(np.asarray(leaf)).view(np.uint8)
        for i, leaf in enumerate(leaves)
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "paths": paths,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "treedef": str(treedef),
        # per-leaf integrity manifest, verified on restore
        "crc32": [int(zlib.crc32(a.tobytes())) for a in arrays.values()],
    }
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump(meta, f)
    for name in ("arrays.npz", "tree.json"):
        _fsync_path(os.path.join(tmp, name))
    _fsync_path(tmp)
    if os.path.exists(final):
        os.rename(final, final + ".old")
    os.rename(tmp, final)
    _fsync_path(directory)
    old = final + ".old"
    if os.path.exists(old):
        import shutil
        shutil.rmtree(old)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith((".tmp", ".old"))]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    like: Any,
    mesh=None,
    pspecs: Any = None,
) -> Any:
    """Restores into the structure of ``like``. With (mesh, pspecs) the
    leaves are placed with the *target* sharding — the elastic path.

    Integrity: every leaf's bytes are checked against the crc32 manifest
    recorded at save time (when present — pre-manifest checkpoints load
    unverified); a truncated / unreadable archive or a checksum mismatch
    raises :class:`CheckpointCorruptError` naming the step dir, so the
    caller can quarantine and rebuild instead of consuming garbage.
    """
    import json as _json

    import ml_dtypes  # noqa: F401 — registers bf16 etc. with numpy

    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.isdir(path):
        raise FileNotFoundError(f"no checkpoint step dir {path!r}")
    meta_path = os.path.join(path, "tree.json")
    if not os.path.isfile(meta_path):
        raise CheckpointCorruptError(
            f"checkpoint {path!r} has no tree.json — partial or torn write")
    with open(meta_path) as f:
        try:
            meta = _json.load(f)
        except ValueError as e:
            raise CheckpointCorruptError(
                f"checkpoint {path!r} has unreadable tree.json: {e}") from e
    _, like_leaves, treedef = _flatten_with_paths(like)
    if len(meta["paths"]) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(meta['paths'])} leaves but the restore "
            f"template has {len(like_leaves)} — tree structure mismatch")
    crcs = meta.get("crc32")
    raw = []
    try:
        data = np.load(os.path.join(path, "arrays.npz"))
        for i in range(len(like_leaves)):
            raw.append(data[f"a{i}"])
    except Exception as e:
        raise CheckpointCorruptError(
            f"checkpoint {path!r} payload arrays.npz is corrupt or "
            f"truncated ({type(e).__name__}: {e})") from e
    if crcs is not None:
        for i, a in enumerate(raw):
            got = int(zlib.crc32(np.ascontiguousarray(a).tobytes()))
            if got != crcs[i]:
                raise CheckpointCorruptError(
                    f"checkpoint {path!r} leaf {meta['paths'][i]!r} failed "
                    f"its crc32 check (stored {crcs[i]}, recomputed {got})"
                    " — payload corrupted on disk")
    leaves = [
        raw[i].view(np.dtype(meta["dtypes"][i])).reshape(meta["shapes"][i])
        for i in range(len(like_leaves))
    ]
    if mesh is not None and pspecs is not None:
        from jax.sharding import NamedSharding

        spec_leaves = treedef.flatten_up_to(pspecs)
        leaves = [
            jax.device_put(l, NamedSharding(mesh, s))
            for l, s in zip(leaves, spec_leaves)
        ]
    else:
        leaves = [jnp.asarray(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    """Async wrapper: snapshot now, write in the background.

    A failed background write (disk full, permissions, torn filesystem) is
    captured and re-raised at the next :meth:`wait` or :meth:`save_async`
    — the failure surfaces at a call site instead of dying silently with
    the daemon thread.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"background checkpoint write to {self.directory!r} "
                f"failed") from err

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        # device→host snapshot happens here, synchronously (consistency);
        # serialization + fsync happen on the thread.
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:   # surfaces at the next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith((".tmp", ".old")))
        import shutil
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
