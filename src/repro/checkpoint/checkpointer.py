"""Checkpointing: atomic, async, sharding-aware, elastic.

Layout: ``<dir>/step_<k>/``
  * ``tree.json``     — pytree structure + per-leaf dtype/shape + pspec
  * ``arrays.npz``    — leaf data, keyed by flattened index

Fault-tolerance properties:
  * **atomic** — written to ``step_<k>.tmp`` then os.rename'd: a crash
    mid-write never corrupts the latest checkpoint;
  * **async**  — ``Checkpointer.save_async`` snapshots to host memory
    synchronously (cheap) and writes on a background thread, so the train
    loop is blocked only for the device→host copy;
  * **elastic** — restore takes the *target* mesh + spec tree and
    ``jax.device_put``s each leaf with the new sharding: a checkpoint
    written on N chips restores onto M ≠ N chips (scale up/down without
    retraining) — see distributed/elastic.py for the mesh-shape change
    helper and tests/test_checkpoint.py for the roundtrip proof.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    paths, leaves, treedef = _flatten_with_paths(tree)
    # npz cannot round-trip extended dtypes (bf16 → void); store raw bytes
    # and reconstruct from the recorded dtype/shape on restore.
    arrays = {
        f"a{i}": np.ascontiguousarray(np.asarray(leaf)).view(np.uint8)
        for i, leaf in enumerate(leaves)
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "paths": paths,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "treedef": str(treedef),
    }
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        os.rename(final, final + ".old")
    os.rename(tmp, final)
    old = final + ".old"
    if os.path.exists(old):
        import shutil
        shutil.rmtree(old)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith((".tmp", ".old"))]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    like: Any,
    mesh=None,
    pspecs: Any = None,
) -> Any:
    """Restores into the structure of ``like``. With (mesh, pspecs) the
    leaves are placed with the *target* sharding — the elastic path."""
    import json as _json

    import ml_dtypes  # noqa: F401 — registers bf16 etc. with numpy

    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "tree.json")) as f:
        meta = _json.load(f)
    _, like_leaves, treedef = _flatten_with_paths(like)
    if len(meta["paths"]) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(meta['paths'])} leaves but the restore "
            f"template has {len(like_leaves)} — tree structure mismatch")
    leaves = [
        data[f"a{i}"].view(np.dtype(meta["dtypes"][i])).reshape(
            meta["shapes"][i])
        for i in range(len(like_leaves))
    ]
    if mesh is not None and pspecs is not None:
        from jax.sharding import NamedSharding

        spec_leaves = treedef.flatten_up_to(pspecs)
        leaves = [
            jax.device_put(l, NamedSharding(mesh, s))
            for l, s in zip(leaves, spec_leaves)
        ]
    else:
        leaves = [jnp.asarray(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    """Async wrapper: snapshot now, write in the background."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        # device→host snapshot happens here, synchronously (consistency);
        # serialization + fsync happen on the thread.
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith((".tmp", ".old")))
        import shutil
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
