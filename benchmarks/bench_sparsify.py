"""Paper Figure 5 — uniform sparsification baseline vs FrogWild.

Keep each edge w.p. q, run 2 PR iterations; FrogWild should win on time at
comparable accuracy (paper: "significantly worse running time, comparable
accuracy").
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_graph, bench_pi, emit, timeit
from repro.core import (FrogWildConfig, frogwild, frogwild_run,
                        normalized_mass_captured, power_iteration,
                        sparsify_uniform)


def main():
    g = bench_graph()
    pi = bench_pi()
    rows = []
    for q in (0.5, 0.3, 0.1):
        gs = sparsify_uniform(g, keep_prob=q, seed=1)
        us = timeit(jax.jit(lambda: power_iteration(gs, num_iters=2)),
                    repeats=1)
        est = power_iteration(gs, num_iters=2)
        m = float(normalized_mass_captured(est, pi, 100))
        rows.append((f"fig5/sparsify_q{q}_2iter", us, f"mass100={m:.4f}"))
    cfg = FrogWildConfig(num_frogs=800_000, num_steps=4, p_s=0.7,
                         erasure="channel", num_shards=20)
    fn = jax.jit(lambda k: frogwild_run(g, cfg, k).counts)
    us = timeit(lambda: fn(jax.random.PRNGKey(0)), repeats=1)
    res = frogwild(g, cfg, seed=0)
    m = float(normalized_mass_captured(res.pi_hat, pi, 100))
    rows.append(("fig5/frogwild_ps0.7", us, f"mass100={m:.4f}"))
    return emit(rows)


if __name__ == "__main__":
    main()
