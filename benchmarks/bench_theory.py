"""Theorem 1 validation — empirical captured-mass error vs the analytic ε.

For each (N, p_s): ε_emp = μ_k(π) − μ_k(π̂) must lie below the bound (4)
with p_∩ from Theorem 2. (The bound is loose — what matters is it HOLDS.)
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_graph, bench_pi, emit
from repro.core import FrogWildConfig, frogwild, theory
from repro.core.metrics import mass_captured


def main():
    g = bench_graph()
    pi = bench_pi()
    k, t, delta = 50, 8, 0.1
    pi_inf = float(pi.max())
    _, idx = jax.lax.top_k(pi, k)
    mu_opt = float(pi[idx].sum())
    rows = []
    for N in (100_000, 800_000):
        for p_s in (1.0, 0.4):
            cfg = FrogWildConfig(num_frogs=N, num_steps=t, p_s=p_s,
                                 erasure="channel", num_shards=20)
            res = frogwild(g, cfg, seed=0)
            mu_hat = float(mass_captured(res.pi_hat, pi, k))
            eps_emp = mu_opt - mu_hat
            p_cap = theory.p_cap_bound(g.n, t, pi_inf, 0.15)
            eps_bound = theory.epsilon_bound(0.15, t, k, delta, N, p_s, p_cap)
            holds = eps_emp <= eps_bound
            rows.append((f"thm1/N{N}_ps{p_s}", 0.0,
                         f"eps_emp={eps_emp:.4f} eps_bound={eps_bound:.4f} "
                         f"holds={holds}"))
    return emit(rows)


if __name__ == "__main__":
    main()
