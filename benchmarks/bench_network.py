"""Paper Figure 8 — network bytes vs number of initial walkers (linear) and
vs p_s (proportional): the cost-model view validated against the engine's
measured counters in tests/test_multidevice.py.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.engine.netcost import frogwild_bytes_model, pagerank_bytes_model


def main():
    rows = []
    S, t = 20, 4
    byN = {}
    for N in (100_000, 200_000, 400_000, 800_000):
        b = frogwild_bytes_model(N, t, 0.15, 0.7, S).total
        byN[N] = b
        rows.append((f"fig8/bytes_N{N}", b / 1e6, "unit=MB ps=0.7"))
    # linearity check: doubling N doubles bytes
    ratio = byN[800_000] / byN[400_000]
    rows.append(("fig8/linearity_800k_over_400k", 0.0, f"ratio={ratio:.3f}"))
    for ps in (1.0, 0.7, 0.4, 0.1):
        b = frogwild_bytes_model(800_000, t, 0.15, ps, S).total
        rows.append((f"fig8/bytes_ps{ps}", b / 1e6, "unit=MB N=800k"))
    pr = pagerank_bytes_model(65_536, 2, S).total
    rows.append(("fig8/bytes_graphlab_2iter", pr / 1e6, "unit=MB"))
    return emit(rows)


if __name__ == "__main__":
    main()
