"""Query-serving benchmark: indexed stitching vs from-scratch restart,
gathered vs sharded-slab serving, and QueryHandle (anytime) driving — all
through the :class:`~repro.service.FrogWildService` facade.

Serves a batch of (ε, δ)-planned top-k and PPR queries over the same graph
and the same per-query walk budgets:

* **indexed** — the walk-index query engine: one offline segment-index
  build (owned by the service, amortized across all queries), then the
  continuous-batching scheduler stitching ``⌊t/L⌋`` segment gathers +
  ``t mod L`` residual steps per walk, many queries per device wave.
* **indexed, sharded slab** — the same scheduler serving from per-shard
  ``[shard_size, R]`` blocks with no reassembly (one fused ``lax.scan``
  wave program per AOT-ladder bucket here on one device, one ``shard_map``
  program on a mesh) — the cost of the 4·n·R/S per-device memory win.
* **service handle** — the same queries as **indexed** but submitted as
  :class:`~repro.service.QueryHandle` futures and driven by ``poll()`` +
  ``partial()`` (one anytime snapshot per wave) — the row pins the
  handle-mode overhead so later PRs can't regress it silently.
* **restart** — the pre-index serving story: every query reruns the full
  ``t``-superstep walk from scratch, one query at a time.
* **supervised / faulted** — the fault-tolerance arms (PR 6): the same
  sharded workload with the wave supervisor armed and an *empty* fault
  plan (byte-identical answers; the row records the supervision overhead,
  acceptance target < 5%), and with one of the shards evicted mid-stream
  (degraded serving: renormalized tallies, Theorem-1-widened
  ``epsilon_bound``).

* **gateway faulted** — the gateway-tier fault-tolerance arm (PR 8): a
  seeded replica crash mid-query, measuring the survived query's
  failover latency, plus the shed rate when the submit stream overruns
  the backpressure threshold (structured 503s, not a lock convoy).

Emits ``BENCH_query.json`` with queries/sec and p50/p99 latency for all
paths, plus the index build cost. ``--smoke`` instead runs a tiny
gathered-vs-fused-vs-legacy-loop-vs-handle dispatch equivalence sweep, an
AOT-ladder recompile-count gate, a handle-mode overhead gate, plus two
fault-injection sweeps — scheduler-level (zero-fault byte-identity +
seeded shard-loss degradation) and gateway-level (crash mid-query →
failover byte-identity + quarantine + restart over the same slab; stall
→ quarantine + reroute; overload → shed not block) — no timing, no JSON
rewrite; wired into ``scripts/ci_tier1.sh --bench-smoke``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro import (FrogWildService, Gateway, RuntimeConfig, ServingConfig,
                   ShardConfig)
from repro.config import FrogWildConfig, KernelConfig
from repro.core import theory
from repro.core.frogwild import _frogwild_walks
from repro.distributed.faults import FaultPlan
from repro.gateway import GatewayOverloadError
from repro.graph import chung_lu_powerlaw
from repro.kernels import ops
from repro.distributed.runtime import wave_trace_count
from repro.query import plan_query
from repro.query.engine import _plain_steps, sample_walk_lengths

N_GRAPH = 32_768
NUM_QUERIES = 24
NUM_SHARDS = 8
EPSILON, DELTA, K = 0.3, 0.1, 10


def _serving(R=8, L=4, max_walks=16_384, max_queries=12, max_steps=None):
    return ServingConfig(segments_per_vertex=R, segment_len=L,
                         build_shards=8, max_walks=max_walks,
                         max_queries=max_queries,
                         max_steps=max_steps
                         if max_steps is not None else 32)


def _stream(num=None):
    """The benchmark's mixed request stream — the single definition of its
    shape, shared by the indexed/handle paths and the restart baseline so
    the rows always compare the same workload."""
    for i in range(NUM_QUERIES if num is None else num):
        yield ("ppr", 17 * i + 1) if i % 3 == 2 else ("topk", None)


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _submit_all(svc, num=None, early_stop=False):
    handles = []
    for kind, source in _stream(num):
        if kind == "ppr":
            h = svc.ppr(source, k=K, epsilon=EPSILON, delta=DELTA,
                        early_stop=early_stop)
        else:
            h = svc.topk(k=K, epsilon=EPSILON, delta=DELTA,
                         early_stop=early_stop)
        assert h.admitted
        handles.append(h)
    return handles


def smoke():
    """Gathered vs sharded (fused and legacy-loop dispatch) vs handle-driven
    serving equivalence at tiny sizes, plus the AOT-ladder recompile gate
    and the handle-mode overhead gate. All dispatch paths share one key
    stream, so on the same slab their answers must agree exactly — any
    divergence is a dispatch regression and fails tier-1
    (``scripts/ci_tier1.sh --bench-smoke``).
    """
    g = chung_lu_powerlaw(n=768, avg_out_deg=6, seed=0)
    serving = _serving(R=6, L=2, max_walks=512, max_queries=3, max_steps=10)
    results = {}
    for name, shards, stitch, dispatch in [
        ("gathered", 1, "xla", "fused"),
        ("sharded", 4, "xla", "fused"),
        ("sharded_loop", 4, "xla", "loop"),
        ("sharded_kernel", 4, "ref", "fused"),
    ]:
        svc = FrogWildService.open(g, RuntimeConfig(
            kernel=KernelConfig(stitch_impl=stitch),
            runtime=ShardConfig(num_shards=shards, seed=7),
            serving=dataclasses.replace(serving, sharded_dispatch=dispatch)))
        handles = _submit_all(svc, num=4)
        results[name] = sorted(svc.drain(), key=lambda r: r.rid)
        print(f"smoke query_serving {name} OK "
              f"(dispatch={svc.scheduler.dispatch})")
    # handle-driven path (poll + partial per wave) on the gathered slab
    svc = FrogWildService.open(g, RuntimeConfig(
        runtime=ShardConfig(num_shards=1, seed=7), serving=serving))
    handles = _submit_all(svc, num=4)
    while not all(h.poll() for h in handles):
        for h in handles:
            if not h.done():
                h.partial()                    # anytime snapshot each wave
    results["handle"] = sorted((h.result() for h in handles),
                               key=lambda r: r.rid)
    print("smoke query_serving handle OK (poll-driven)")
    for name in ("sharded", "sharded_loop", "sharded_kernel", "handle"):
        for a, b in zip(results["gathered"], results[name]):
            assert (a.vertices == b.vertices).all(), (name, a.rid)
            assert np.allclose(a.scores, b.scores), (name, a.rid)
    print("smoke OK: gathered, fused-sharded, legacy-loop, and "
          "handle-driven serving answers identical")

    # AOT-ladder recompile gate: warm the whole bucket ladder, then a
    # shifting topk/PPR mix with per-query walk budgets spanning every
    # bucket must never trace another wave program.
    svc = FrogWildService.open(g, RuntimeConfig(
        runtime=ShardConfig(num_shards=4, seed=7),
        serving=dataclasses.replace(serving, aot_warmup=True)))
    svc.scheduler                              # build + warm_ladder()
    traced = wave_trace_count()
    for walks in (40, 90, 200, 500):
        svc.topk(k=5, num_walks=walks)
        svc.ppr(7, k=5, num_walks=max(walks // 2, 1))
        svc.drain()
    assert wave_trace_count() == traced, "query-mix change retraced a wave"
    print("smoke OK: zero wave retraces across a mixed sweep after ladder "
          "warmup")

    # handle-mode overhead gate: poll()+partial() driving must stay within
    # shouting distance of drain() on the same warmed service — the
    # per-poll top-k finalize is O(n), not a full-n sort (the PR 5
    # handle_vs_drain regression). Generous threshold: timing at smoke
    # sizes is noisy; the real ratio is gated in BENCH_query.json.
    svc = FrogWildService.open(g, RuntimeConfig(
        runtime=ShardConfig(num_shards=1, seed=7), serving=serving))
    def drain_pass():
        _submit_all(svc, num=4)
        out = svc.drain()
        svc.scheduler.finished = []
        return out

    def handle_pass():
        hs = _submit_all(svc, num=4)
        while not all(h.poll() for h in hs):
            for h in hs:
                if not h.done():
                    h.partial()
        out = [h.result() for h in hs]
        svc.scheduler.finished = []
        return out

    drain_pass(); handle_pass()                # warm the ladder programs
    dt_drain = min(_timed(drain_pass) for _ in range(3))
    dt_handle = min(_timed(handle_pass) for _ in range(3))
    ratio = dt_drain / dt_handle
    assert ratio > 0.25, f"handle-driven serving {1/ratio:.1f}x slower " \
                         f"than drain at smoke size"
    print(f"smoke OK: handle-vs-drain overhead gate "
          f"(handle/drain qps ratio {ratio:.2f} > 0.25)")

    # fault-injection sweep: supervision armed with an *empty* plan must
    # stay byte-identical to the plain sharded path; a seeded shard loss
    # must serve degraded with the Theorem-1 widened bound (never an
    # unflagged answer).
    def sharded_svc(faults):
        return FrogWildService.open(g, RuntimeConfig(
            runtime=ShardConfig(num_shards=4, seed=7), serving=serving,
            faults=faults))

    svc = sharded_svc(FaultPlan())
    _submit_all(svc, num=4)
    for a, b in zip(results["sharded"],
                    sorted(svc.drain(), key=lambda r: r.rid)):
        assert (a.vertices == b.vertices).all(), ("supervised", a.rid)
        assert np.allclose(a.scores, b.scores), ("supervised", a.rid)
        assert not b.degraded
    print("smoke query_serving supervised-zero-fault OK (byte-identical)")

    import math
    svc = sharded_svc(FaultPlan(shard_losses=((0, 1),)))
    _submit_all(svc, num=4)
    degraded = sorted(svc.drain(), key=lambda r: r.rid)
    assert svc.lost_shards == frozenset({1})
    for r in degraded:
        assert r.degraded and r.walks_lost > 0, r.rid
        want = theory.epsilon_bound(svc.config.p_T, r.num_steps, K, DELTA,
                                    r.num_walks, 1.0, 0.0)
        assert math.isclose(r.epsilon_bound, want), r.rid
    print("smoke query_serving faulted OK (degraded + widened bound)")

    # gateway sweep (PR 7): a 2-replica gateway must answer a cold miss
    # byte-identically to a fresh direct service under the same config,
    # and a dominated repeat must come from the cache with zero new walks
    # — the same object, no waves run. Uses a geometry where ε=0.4 plans
    # are feasible (at max_steps=10 every certificate is honestly > 1).
    gserving = ServingConfig(segments_per_vertex=12, segment_len=3,
                             build_shards=2, max_walks=512, max_queries=3,
                             max_steps=32)
    gcfg = RuntimeConfig(runtime=ShardConfig(num_shards=1, seed=7),
                         serving=gserving)
    want = FrogWildService.open(g, gcfg).topk(
        k=K, epsilon=0.4, delta=DELTA).result()
    with Gateway.open(g, gcfg, replicas=2) as gw:
        got = gw.topk(k=K, epsilon=0.4, delta=DELTA).result()
        assert (np.asarray(got.vertices) == np.asarray(want.vertices)).all()
        assert (np.asarray(got.scores) == np.asarray(want.scores)).all()
        assert got.num_walks == want.num_walks
        assert got.epsilon_bound == want.epsilon_bound
        print("smoke gateway cold-miss OK (byte-identical to direct service)")
        waves = gw.pool.total_waves_run()
        rep = gw.topk(k=K, epsilon=0.4, delta=DELTA)
        assert rep.source == "cache" and rep.result() is got
        weaker = gw.topk(k=K, epsilon=0.6, delta=0.2)
        assert weaker.source == "cache" and weaker.result() is got
        assert gw.pool.total_waves_run() == waves
        s = gw.stats()
        assert s["cache_hits"] == 2 and s["cache"]["dominated_hits"] == 2
        print("smoke gateway dominated-hit OK (zero new walks, verbatim "
              "result)")
        # in-flight join identity: an identical duplicate of a live query
        # rides its handle (zero walks of its own) and settles with the
        # parent's QueryResult object verbatim.
        live = gw.topk(k=K + 2, epsilon=0.4, delta=DELTA)
        dup = gw.topk(k=K + 2, epsilon=0.4, delta=DELTA)
        assert live.source == "live" and dup.source == "joined"
        assert dup.result() is live.result()
    print("smoke gateway in-flight join OK (verbatim parent result)")

    # gateway fault sweep (PR 8) — the tier-1 acceptance gates.
    # 1. seeded replica crash mid-query: the query fails over and the
    #    survived answer is byte-identical to the fault-free run (`want`,
    #    the direct-service reference the zero-fault gateway matched
    #    above); the sick replica is quarantined, then restarted over the
    #    SAME shared slab (object identity, zero index rebuild).
    crash_cfg = dataclasses.replace(
        gcfg, faults=FaultPlan(seed=3, replica_crashes=((0, 0),)))
    with Gateway.open(g, crash_cfg, replicas=2, cache=False) as gwf:
        h = gwf.topk(k=K, epsilon=0.4, delta=DELTA)
        assert h.replica == 0                    # routed to the doomed one
        r = h.result()
        assert h.replica == 1 and gwf.metrics.failovers == 1
        assert (np.asarray(r.vertices) == np.asarray(want.vertices)).all()
        assert (np.asarray(r.scores) == np.asarray(want.scores)).all()
        assert r.epsilon_bound == want.epsilon_bound
        assert gwf.pool.breaker_state(0) == "open"
        assert gwf.pool.routable() == [1]        # quarantined out of route
        fresh = gwf.pool.restart_replica(0)
        assert fresh.ensure_index() is gwf.pool.index
    print("smoke gateway crash-failover OK (byte-identical, quarantine + "
          "restart over the shared slab)")

    # 2. stall past the heartbeat deadline: quarantine + reroute, and the
    #    rerouted answer is still the fault-free answer.
    stall_cfg = dataclasses.replace(
        gcfg, faults=FaultPlan(seed=3, replica_stalls=((0, 0, 0.6),)))
    with Gateway.open(g, stall_cfg, replicas=2, cache=False,
                      heartbeat_timeout_s=0.25) as gws:
        h = gws.topk(k=K, epsilon=0.4, delta=DELTA)
        r = h.result()
        assert h.replica == 1 and gws.pool.breaker_state(0) == "open"
        assert (np.asarray(r.vertices) == np.asarray(want.vertices)).all()
    print("smoke gateway stall OK (quarantine + reroute)")

    # 3. overload: the submit is shed with a structured Retry-After —
    #    never a blocked caller.
    with Gateway.open(g, gcfg, replicas=2, cache=False,
                      shed_backlog_walks=1) as gwo:
        h = gwo.topk(k=K, epsilon=0.4, delta=DELTA)
        try:
            gwo.ppr(3, k=K, epsilon=0.4, delta=DELTA)
            raise AssertionError("overloaded submit was not shed")
        except GatewayOverloadError as e:
            assert e.retry_after_s > 0 and gwo.metrics.sheds == 1
        h.result()
    print("smoke gateway overload OK (shed with Retry-After, not blocked)")

    # incremental-refresh gate (PR 10): mutate ~1% of vertices' successor
    # lists (the contiguous id window with minimum in-degree, so the walk
    # trajectories touching it are as cold as the generator allows), then
    # require (a) the refresh re-walked exactly the invalidated segments
    # and they are a small fraction of the slab, (b) the refreshed slab is
    # byte-identical to a from-scratch build at the new epoch — endpoints
    # and visited masks — and (c) a query in flight across the epoch
    # commit finishes byte-identically to a never-mutated service.
    from repro.dynamic import MutationBatch, refresh_walk_index
    from repro.dynamic import apply_mutations as apply_muts
    from repro.query import WalkIndexConfig
    from repro.query.index import _build_walk_index

    icfg = WalkIndexConfig(segments_per_vertex=6, segment_len=3,
                           num_shards=4)
    idx0 = _build_walk_index(g, icfg)
    indeg = np.bincount(np.asarray(g.col_idx), minlength=g.n)
    w = max(1, g.n // 100)
    cs = np.concatenate([[0], np.cumsum(indeg)])
    lo = int(np.argmin(cs[w:] - cs[:-w]))
    batch = MutationBatch.edges(
        insert=[(v, (v * 7 + 13) % g.n) for v in range(lo, lo + w)])
    g2, changed = apply_muts(g, batch)
    new_idx, report = refresh_walk_index(idx0, g2, changed)
    assert report.segments_rebuilt == report.stale_segments
    assert report.segments_rebuilt <= report.stale_rows * 6
    assert report.segments_rebuilt < report.total_segments // 4, (
        f"1% cold-window mutation invalidated "
        f"{report.segments_rebuilt}/{report.total_segments} segments — "
        f"invalidation has lost its locality")
    full = _build_walk_index(g2, icfg)
    assert np.array_equal(np.asarray(new_idx.endpoints),
                          np.asarray(full.endpoints))
    assert np.array_equal(new_idx.visited_blocks, full.visited_blocks)
    assert new_idx.graph_epoch == 1
    print(f"smoke dynamic refresh OK ({report.segments_rebuilt}/"
          f"{report.total_segments} segments rebuilt, byte-identical to "
          f"full rebuild at epoch 1)")

    dcfg = RuntimeConfig(
        runtime=ShardConfig(num_shards=1, seed=7),
        serving=ServingConfig(segments_per_vertex=6, segment_len=3,
                              build_shards=4, max_walks=256, max_queries=2,
                              max_steps=32))
    want_dyn = FrogWildService.open(g, dcfg).topk(
        k=K, epsilon=0.4, delta=DELTA, num_walks=1024,
        early_stop=False).result()
    svc = FrogWildService.open(g, dcfg)
    h = svc.topk(k=K, epsilon=0.4, delta=DELTA, num_walks=1024,
                 early_stop=False)
    h.poll()                                   # in flight across the commit
    svc.apply_mutations(batch)
    assert svc.graph_epoch == 1
    r = h.result()
    assert r.epoch == 0
    assert (np.asarray(r.vertices) == np.asarray(want_dyn.vertices)).all()
    assert (np.asarray(r.scores) == np.asarray(want_dyn.scores)).all()
    assert r.num_walks == want_dyn.num_walks
    r_new = svc.topk(k=K, epsilon=0.4, delta=DELTA).result()
    assert r_new.epoch == 1
    print("smoke dynamic epoch-pinning OK (in-flight query byte-identical "
          "to a never-mutated service; new admissions on epoch 1)")


def _restart_latencies(g, plan, p_T=0.15):
    """One full from-scratch walk program per query (the no-index baseline)."""
    cfg = FrogWildConfig(num_frogs=plan.num_walks, num_steps=plan.num_steps,
                         p_T=p_T)
    topk_run = jax.jit(lambda k: _frogwild_walks(g, cfg, k).counts)

    def ppr_counts(source, key):
        k_tau, k_walk = jax.random.split(key)
        pos0 = jnp.full((plan.num_walks,), source, jnp.int32)
        tau = sample_walk_lengths(k_tau, plan.num_walks, p_T, plan.num_steps)
        pos = _plain_steps(g.row_ptr, g.col_idx, g.out_deg, pos0, tau,
                           k_walk, plan.num_steps)
        return ops.frog_count(pos, g.n, impl="ref")

    ppr_run = jax.jit(ppr_counts)
    # warm both programs so the measured latencies are steady-state
    jax.block_until_ready(topk_run(jax.random.PRNGKey(0)))
    jax.block_until_ready(ppr_run(jnp.int32(1), jax.random.PRNGKey(0)))

    lat = []
    for i, (kind, source) in enumerate(_stream()):
        key = jax.random.PRNGKey(100 + i)
        t0 = time.perf_counter()
        if kind == "ppr":
            counts = ppr_run(jnp.int32(source), key)
        else:
            counts = topk_run(key)
        counts = np.asarray(counts)
        np.argsort(-counts, kind="stable")[:K]       # same finalize work
        lat.append(time.perf_counter() - t0)
    return np.asarray(lat)


def main():
    rows = []
    g = chung_lu_powerlaw(n=N_GRAPH, avg_out_deg=12, seed=0)
    plan = plan_query(K, EPSILON, DELTA)
    serving = _serving(max_steps=plan.num_steps)

    svc = FrogWildService.open(g, RuntimeConfig(serving=serving))
    t0 = time.perf_counter()
    index = svc.ensure_index()
    build_s = time.perf_counter() - t0
    rows.append(("query/index_build", build_s * 1e6,
                 f"n={g.n} R={index.segments_per_vertex} "
                 f"L={index.segment_len} slab_mb="
                 f"{index.endpoints.nbytes / 1e6:.1f}"))

    # one service per dispatch: its wave program compiles once and every
    # later wave reuses it (the steady-state serving regime).
    def serve(s):
        _submit_all(s)
        out = s.drain()
        s.scheduler.finished = []
        return out

    # handle-driven serving: same queries, driven by poll() with one
    # partial() anytime snapshot per wave — pins the QueryHandle overhead.
    def serve_handles(s):
        handles = _submit_all(s, early_stop=True)
        while not all(h.poll() for h in handles):
            for h in handles:
                if not h.done():
                    h.partial()
        out = [h.result() for h in handles]
        s.scheduler.finished = []
        return out

    # Comparability (PR 9): drain-driven and handle-driven reps are
    # interleaved over the same warmed service with the min taken, so
    # handle_vs_drain measures the poll()/partial() overhead — not
    # measurement-order luck on a noisy box.
    serve(svc)                                       # warm the wave programs
    serve_handles(svc)
    dts_idx, dts_h = [], []
    results = results_h = None
    for _ in range(3):                               # interleaved reps
        t0 = time.perf_counter()
        results = serve(svc)
        dts_idx.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        results_h = serve_handles(svc)
        dts_h.append(time.perf_counter() - t0)
    dt_idx, dt_h = min(dts_idx), min(dts_h)
    lat_idx = np.asarray([r.latency_s for r in results])
    qps_idx = NUM_QUERIES / dt_idx
    rows.append(("query/indexed_serve", dt_idx * 1e6 / NUM_QUERIES,
                 f"qps={qps_idx:.1f} p50_ms={np.percentile(lat_idx, 50) * 1e3:.1f} "
                 f"p99_ms={np.percentile(lat_idx, 99) * 1e3:.1f}"))

    lat_h = np.asarray([r.latency_s for r in results_h])
    qps_h = NUM_QUERIES / dt_h
    rows.append(("query/query_service_handle", dt_h * 1e6 / NUM_QUERIES,
                 f"qps={qps_h:.1f} p50_ms={np.percentile(lat_h, 50) * 1e3:.1f} "
                 f"p99_ms={np.percentile(lat_h, 99) * 1e3:.1f} "
                 f"vs_drain={qps_h / qps_idx:.3f} (interleaved min-of-3)"))

    # gateway cache-hit serving (PR 7): the same stream through a
    # 2-replica gateway. The first pass runs live (identical concurrent
    # top-k requests dedup onto one in-flight query — the join counter)
    # and warms the (ε, δ)-aware cache; the timed second pass is then
    # answered entirely by dominated certificates — zero walks, so the
    # row measures the cache's lookup path against handle-mode serving.
    def gw_stream(gw):
        handles = [(gw.ppr(source, k=K, epsilon=EPSILON, delta=DELTA)
                    if kind == "ppr"
                    else gw.topk(k=K, epsilon=EPSILON, delta=DELTA))
                   for kind, source in _stream()]
        for h in handles:
            h.result()
        return handles

    gw = Gateway.open(g, RuntimeConfig(serving=serving), replicas=2)
    gw_stream(gw)                                    # live pass: warm cache
    t0 = time.perf_counter()
    hit_handles = gw_stream(gw)
    dt_hit = time.perf_counter() - t0
    assert all(h.source == "cache" for h in hit_handles)
    qps_hit = NUM_QUERIES / dt_hit
    gstats = gw.stats()
    hit_rate, join_rate = gstats["hit_rate"], gstats["join_rate"]
    gw.close()
    rows.append(("query/query_cache_hit", dt_hit * 1e6 / NUM_QUERIES,
                 f"qps={qps_hit:.0f} vs_handle={qps_hit / qps_h:.0f}x "
                 f"hit_rate={hit_rate:.2f} join_rate={join_rate:.2f} "
                 f"replicas=2 (dominated certs, zero walks)"))

    # sharded-slab serving: per-shard blocks, no slab reassembly (the fused
    # single-dispatch wave on this 1-device bench: one lax.scan program per
    # ladder bucket against the stacked slab; 4·n·R/S bytes of slab
    # resident per device on a mesh instead of 4·n·R).
    #
    # The zero-fault supervision arm rides the same workload with the
    # injector attached (empty plan) and the per-wave timeout armed.
    # Comparability (PR 9): both services serve the same warmed slab with
    # identical wave settings apart from the armed supervisor, both are
    # fully warmed, and the timed reps are interleaved with the min taken
    # — so overhead_vs_sharded measures supervision, not compile state or
    # measurement-order luck.
    svc_sh = FrogWildService.open(
        g, RuntimeConfig(runtime=ShardConfig(num_shards=NUM_SHARDS),
                         serving=serving),
        index=index)
    svc_sup = FrogWildService.open(
        g, RuntimeConfig(runtime=ShardConfig(num_shards=NUM_SHARDS),
                         serving=dataclasses.replace(serving,
                                                     wave_timeout_s=60.0),
                         faults=FaultPlan()),
        index=index)
    serve(svc_sh)                                    # warm both program sets
    serve(svc_sup)
    dts_sh, dts_sup = [], []
    results_sh = results_sup = None
    for _ in range(3):                               # interleaved reps
        t0 = time.perf_counter()
        results_sh = serve(svc_sh)
        dts_sh.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        results_sup = serve(svc_sup)
        dts_sup.append(time.perf_counter() - t0)
    dt_sh, dt_sup = min(dts_sh), min(dts_sup)
    lat_sh = np.asarray([r.latency_s for r in results_sh])
    qps_sh = NUM_QUERIES / dt_sh
    slab_mb = index.endpoints.nbytes / 1e6
    rows.append(("query/query_serving_sharded", dt_sh * 1e6 / NUM_QUERIES,
                 f"qps={qps_sh:.1f} p50_ms={np.percentile(lat_sh, 50) * 1e3:.1f} "
                 f"p99_ms={np.percentile(lat_sh, 99) * 1e3:.1f} "
                 f"shards={NUM_SHARDS} slab_mb_per_shard="
                 f"{slab_mb / NUM_SHARDS:.2f} dispatch="
                 f"{svc_sh.scheduler.dispatch}"))

    qps_sup = NUM_QUERIES / dt_sup
    for a, b in zip(results_sh, results_sup):        # still byte-identical
        assert (a.vertices == b.vertices).all() and not b.degraded
    overhead = dt_sup / dt_sh - 1.0
    rows.append(("query/query_serving_supervised", dt_sup * 1e6 / NUM_QUERIES,
                 f"qps={qps_sup:.1f} overhead_vs_sharded="
                 f"{overhead * 100:+.1f}% (zero faults, timeout armed, "
                 f"interleaved min-of-3)"))

    # fault supervision, one shard lost mid-stream: degraded serving.
    svc_flt = FrogWildService.open(
        g, RuntimeConfig(runtime=ShardConfig(num_shards=NUM_SHARDS),
                         serving=serving,
                         faults=FaultPlan(shard_losses=((2, 1),))),
        index=index)
    serve(svc_flt)          # warm; the injected loss fires here (wave 2),
    t0 = time.perf_counter()  # so the timed run is steady-state degraded
    results_flt = serve(svc_flt)
    dt_flt = time.perf_counter() - t0
    qps_flt = NUM_QUERIES / dt_flt
    n_deg = sum(r.degraded for r in results_flt)
    lost_frac = (sum(r.walks_lost for r in results_flt)
                 / sum(r.num_walks + r.walks_lost for r in results_flt))
    bound_widening = np.mean([
        r.epsilon_bound / plan.epsilon_bound
        for r in results_flt if r.degraded]) if n_deg else 1.0
    rows.append(("query/query_serving_faulted", dt_flt * 1e6 / NUM_QUERIES,
                 f"qps={qps_flt:.1f} degraded={n_deg}/{NUM_QUERIES} "
                 f"walks_lost={lost_frac * 100:.1f}% "
                 f"bound_widening={bound_widening:.2f}x "
                 f"(1 of {NUM_SHARDS} shards evicted)"))

    # gateway fault tolerance (PR 8): failover latency and shed rate.
    # One seeded crash of replica 0 at its first pool drive — the query
    # migrates to replica 1 and replays from wave 0; the row's headline
    # is the end-to-end latency of that survived query.
    gw_f = Gateway.open(
        g, RuntimeConfig(serving=serving,
                         faults=FaultPlan(seed=7, replica_crashes=((0, 0),))),
        replicas=2, cache=False)
    t0 = time.perf_counter()
    h_f = gw_f.topk(k=K, epsilon=EPSILON, delta=DELTA)
    h_f.result()
    failover_latency_s = time.perf_counter() - t0
    n_failovers = gw_f.metrics.failovers
    assert h_f.failovers == 1 and n_failovers == 1
    gw_f.close()

    # overload: distinct PPR keys (duplicates would join, and joins are
    # free so they are never shed) against a one-plan backlog budget —
    # everything past the first admitted query is shed with Retry-After.
    gw_s = Gateway.open(g, RuntimeConfig(serving=serving), replicas=2,
                        cache=False, shed_backlog_walks=plan.num_walks)
    n_shed = 0
    for i in range(NUM_QUERIES):
        try:
            gw_s.ppr(17 * i + 1, k=K, epsilon=EPSILON, delta=DELTA)
        except GatewayOverloadError:
            n_shed += 1
    shed_rate = n_shed / NUM_QUERIES
    gw_s.drain()                                     # finish the admitted
    rows.append(("query/query_gateway_faulted", failover_latency_s * 1e6,
                 f"failover_latency_ms={failover_latency_s * 1e3:.1f} "
                 f"failovers={n_failovers} shed_rate={shed_rate:.2f} "
                 f"(replica 0 crashed at wave 0, 2 replicas, "
                 f"backlog_budget={plan.num_walks} walks)"))

    # incremental refresh vs full rebuild (PR 10): mutate a block-aligned
    # cold (minimum in-degree) 1% id window, then time re-walking only the
    # invalidated rows against a from-scratch build at the new epoch. The
    # slab uses the dynamic-serving geometry R=12, L=2: invalidation
    # fan-out scales with R·(L−1) trajectory hops per vertex, so shorter
    # segments (with more of them for stitch diversity) are the geometry a
    # deployment facing continuous mutations would pick — R=8, L=4 leaves
    # ~14% of rows stale per 1% mutation, R=12, L=2 ~6%. Both paths are
    # warmed once (the mutated CSR's edge count re-traces the shared row
    # program) and timed as min-of-3 (this box's wall clock is noisy);
    # byte-equality of the two slabs is asserted, not assumed.
    from repro.dynamic import MutationBatch, refresh_walk_index
    from repro.dynamic import apply_mutations as apply_muts
    from repro.query import WalkIndexConfig
    from repro.query.index import (_build_walk_index,
                                   segment_mask_block_size)

    icfg_dyn = WalkIndexConfig(segments_per_vertex=12, segment_len=2,
                               num_shards=8)
    idx_dyn = _build_walk_index(g, icfg_dyn)
    indeg = np.bincount(np.asarray(g.col_idx), minlength=g.n)
    w = max(1, g.n // 100)
    bs = segment_mask_block_size(g.n)
    cs = np.concatenate([[0], np.cumsum(indeg)])
    starts = np.arange(0, g.n - w + 1, bs)   # block-aligned: fewest dirty
    lo_w = int(starts[np.argmin((cs[w:] - cs[:-w])[starts])])
    batch = MutationBatch.edges(
        insert=[(v, (v * 7 + 13) % g.n) for v in range(lo_w, lo_w + w)])
    g2, changed = apply_muts(g, batch)
    refresh_walk_index(idx_dyn, g2, changed)         # warm the row walker
    refresh_s = np.inf
    for _ in range(3):
        t0 = time.perf_counter()
        idx_r, ref_report = refresh_walk_index(idx_dyn, g2, changed)
        refresh_s = min(refresh_s, time.perf_counter() - t0)
    _build_walk_index(g2, icfg_dyn)                  # warm the full builder
    full_rebuild_s = np.inf
    for _ in range(3):
        t0 = time.perf_counter()
        full_idx = _build_walk_index(g2, icfg_dyn)
        full_rebuild_s = min(full_rebuild_s, time.perf_counter() - t0)
    assert np.array_equal(np.asarray(idx_r.endpoints),
                          np.asarray(full_idx.endpoints))
    assert np.array_equal(idx_r.visited_blocks, full_idx.visited_blocks)
    refresh_speedup = full_rebuild_s / refresh_s
    stale_frac = ref_report.stale_segments / ref_report.total_segments
    rows.append(("query/query_incremental_refresh", refresh_s * 1e6,
                 f"refresh_s={refresh_s:.4f} "
                 f"full_rebuild_s={full_rebuild_s:.4f} "
                 f"speedup={refresh_speedup:.1f}x "
                 f"rows_rebuilt={ref_report.stale_rows} "
                 f"stale_frac={stale_frac:.4f} "
                 f"(1% cold-window mutation, R=12 L=2, "
                 f"byte-identical slabs)"))

    t0 = time.perf_counter()
    lat_rst = _restart_latencies(g, plan)
    dt_rst = time.perf_counter() - t0
    qps_rst = NUM_QUERIES / dt_rst
    rows.append(("query/restart_serve", dt_rst * 1e6 / NUM_QUERIES,
                 f"qps={qps_rst:.1f} p50_ms={np.percentile(lat_rst, 50) * 1e3:.1f} "
                 f"p99_ms={np.percentile(lat_rst, 99) * 1e3:.1f}"))

    speedup = qps_idx / qps_rst
    rows.append(("query/indexed_vs_restart", 0.0,
                 f"speedup={speedup:.2f}x walks/query={plan.num_walks} "
                 f"t={plan.num_steps} "
                 f"rounds={plan.num_rounds(index.segment_len)}"))
    emit(rows)
    emit_json("query", rows, extra={
        "num_queries": NUM_QUERIES,
        "epsilon": EPSILON, "delta": DELTA, "k": K,
        "qps_indexed": round(qps_idx, 2),
        "qps_service_handle": round(qps_h, 2),
        "qps_cache_hit": round(qps_hit, 2),
        "cache_hit_vs_handle": round(qps_hit / qps_h, 1),
        "gateway_hit_rate": round(hit_rate, 4),
        "gateway_join_rate": round(join_rate, 4),
        "qps_sharded": round(qps_sh, 2),
        "qps_restart": round(qps_rst, 2),
        "p50_ms_indexed": round(float(np.percentile(lat_idx, 50)) * 1e3, 2),
        "p99_ms_indexed": round(float(np.percentile(lat_idx, 99)) * 1e3, 2),
        "p50_ms_sharded": round(float(np.percentile(lat_sh, 50)) * 1e3, 2),
        "p99_ms_sharded": round(float(np.percentile(lat_sh, 99)) * 1e3, 2),
        "p50_ms_restart": round(float(np.percentile(lat_rst, 50)) * 1e3, 2),
        "p99_ms_restart": round(float(np.percentile(lat_rst, 99)) * 1e3, 2),
        "index_build_s": round(build_s, 3),
        "num_shards": NUM_SHARDS,
        "slab_mb_per_shard": round(slab_mb / NUM_SHARDS, 3),
        "speedup": round(speedup, 2),
        "sharded_vs_gathered": round(qps_sh / qps_idx, 3),
        "handle_vs_drain": round(qps_h / qps_idx, 3),
        "qps_supervised": round(qps_sup, 2),
        "supervised_overhead": round(overhead, 4),
        "qps_faulted": round(qps_flt, 2),
        "faulted_degraded_queries": int(n_deg),
        "faulted_walks_lost_frac": round(float(lost_frac), 4),
        "faulted_bound_widening": round(float(bound_widening), 3),
        "gateway_failover_latency_ms": round(failover_latency_s * 1e3, 2),
        "gateway_failovers": int(n_failovers),
        "gateway_shed_rate": round(shed_rate, 4),
        "gateway_sheds": int(n_shed),
        "refresh_s": round(refresh_s, 4),
        "full_rebuild_s": round(full_rebuild_s, 4),
        "refresh_speedup": round(refresh_speedup, 2),
        "refresh_rows_rebuilt": int(ref_report.stale_rows),
        "refresh_stale_frac": round(float(stale_frac), 5),
    })


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny gathered-vs-sharded-vs-handle serving "
                         "equivalence sweep; no timing, no JSON rewrite")
    if ap.parse_args().smoke:
        smoke()
    else:
        main()
