"""Query-serving benchmark: indexed stitching vs from-scratch restart, and
gathered vs sharded-slab serving.

Serves a batch of (ε, δ)-planned top-k and PPR queries over the same graph
and the same per-query walk budgets:

* **indexed** — the walk-index query engine: one offline segment-index
  build (amortized across all queries), then the continuous-batching
  ``QueryScheduler`` stitching ``⌊t/L⌋`` segment gathers + ``t mod L``
  residual steps per walk, many queries per device wave.
* **indexed, sharded slab** — the same scheduler serving from per-shard
  ``[shard_size, R]`` slab blocks with no reassembly (the
  ``distributed/runtime.py`` dispatch: host loop here on one device, one
  ``shard_map`` on a mesh) — the row tracks the cost of the 4·n·R/S
  per-device memory win.
* **restart** — the pre-index serving story: every query reruns the full
  ``t``-superstep walk from scratch (``frogwild_run`` for global top-k, a
  masked direct walk for PPR), one query at a time.

Emits ``BENCH_query.json`` with queries/sec and p50/p99 latency for all
three, plus the index build cost — machine-readable trajectory for later
PRs. ``--smoke`` instead runs a tiny gathered-vs-sharded dispatch
equivalence sweep (no timing, no JSON rewrite; wired into
``scripts/ci_tier1.sh --bench-smoke``).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json
from repro.core import FrogWildConfig, frogwild_run
from repro.graph import chung_lu_powerlaw
from repro.kernels import ops
from repro.query import (QueryRequest, QueryScheduler, WalkIndexConfig,
                         build_walk_index, plan_query, shard_walk_index)
from repro.query.engine import _plain_steps, sample_walk_lengths

N_GRAPH = 32_768
NUM_QUERIES = 24
NUM_SHARDS = 8
EPSILON, DELTA, K = 0.3, 0.1, 10


def _requests(num=None):
    reqs = []
    for i in range(NUM_QUERIES if num is None else num):
        if i % 3 == 2:
            reqs.append(QueryRequest(rid=i, kind="ppr", source=17 * i + 1,
                                     k=K, epsilon=EPSILON, delta=DELTA))
        else:
            reqs.append(QueryRequest(rid=i, kind="topk", k=K,
                                     epsilon=EPSILON, delta=DELTA))
    return reqs


def smoke():
    """Gathered vs sharded serving dispatch equivalence at tiny sizes.

    The two waves share one key stream, so on the same slab their answers
    must agree exactly — any divergence is a dispatch regression and fails
    tier-1 (``scripts/ci_tier1.sh --bench-smoke``).
    """
    g = chung_lu_powerlaw(n=768, avg_out_deg=6, seed=0)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=6, segment_len=2, num_shards=2))
    results = {}
    for name, index, impl in [
        ("gathered", idx, "xla"),
        ("sharded", shard_walk_index(idx, 4), "xla"),
        ("sharded_fused", shard_walk_index(idx, 4), "ref"),
    ]:
        sched = QueryScheduler(g, index, max_walks=512, max_queries=3,
                               max_steps=10, seed=7, impl=impl)
        for r in _requests(num=4):
            assert sched.submit(r).admitted
        results[name] = sorted(sched.run(), key=lambda r: r.rid)
        print(f"smoke query_serving {name} OK "
              f"({'loop' if sched.runtime and not sched.runtime.is_mesh else 'dense/mesh'})")
    for name in ("sharded", "sharded_fused"):
        for a, b in zip(results["gathered"], results[name]):
            assert (a.vertices == b.vertices).all(), (name, a.rid)
            assert np.allclose(a.scores, b.scores), (name, a.rid)
    print("smoke OK: gathered and sharded serving answers identical")


def _restart_latencies(g, plan, reqs, p_T=0.15):
    """One full from-scratch walk program per query (the no-index baseline)."""
    cfg = FrogWildConfig(num_frogs=plan.num_walks, num_steps=plan.num_steps,
                         p_T=p_T)
    topk_run = jax.jit(lambda k: frogwild_run(g, cfg, k).counts)

    def ppr_counts(source, key):
        k_tau, k_walk = jax.random.split(key)
        pos0 = jnp.full((plan.num_walks,), source, jnp.int32)
        tau = sample_walk_lengths(k_tau, plan.num_walks, p_T, plan.num_steps)
        pos = _plain_steps(g.row_ptr, g.col_idx, g.out_deg, pos0, tau,
                           k_walk, plan.num_steps)
        return ops.frog_count(pos, g.n, impl="ref")

    ppr_run = jax.jit(ppr_counts)
    # warm both programs so the measured latencies are steady-state
    jax.block_until_ready(topk_run(jax.random.PRNGKey(0)))
    jax.block_until_ready(ppr_run(jnp.int32(1), jax.random.PRNGKey(0)))

    lat = []
    for i, r in enumerate(reqs):
        key = jax.random.PRNGKey(100 + i)
        t0 = time.perf_counter()
        if r.kind == "ppr":
            counts = ppr_run(jnp.int32(r.source), key)
        else:
            counts = topk_run(key)
        counts = np.asarray(counts)
        np.argsort(-counts, kind="stable")[:K]       # same finalize work
        lat.append(time.perf_counter() - t0)
    return np.asarray(lat)


def main():
    rows = []
    g = chung_lu_powerlaw(n=N_GRAPH, avg_out_deg=12, seed=0)
    plan = plan_query(K, EPSILON, DELTA)

    icfg = WalkIndexConfig(segments_per_vertex=8, segment_len=4, num_shards=8)
    t0 = time.perf_counter()
    index = build_walk_index(g, icfg)
    build_s = time.perf_counter() - t0
    rows.append(("query/index_build", build_s * 1e6,
                 f"n={g.n} R={icfg.segments_per_vertex} "
                 f"L={icfg.segment_len} slab_mb="
                 f"{index.endpoints.nbytes / 1e6:.1f}"))

    # one scheduler for warmup + measurement: its wave program compiles once
    # and every later wave reuses it (the steady-state serving regime).
    sched = QueryScheduler(g, index, max_walks=16_384, max_queries=12,
                           max_steps=plan.num_steps)

    def serve_indexed():
        for r in _requests():
            sched.submit(r)
        out = sched.run()
        sched.finished = []
        return out

    serve_indexed()                                  # warm the wave program
    t0 = time.perf_counter()
    results = serve_indexed()
    dt_idx = time.perf_counter() - t0
    lat_idx = np.asarray([r.latency_s for r in results])
    qps_idx = NUM_QUERIES / dt_idx
    rows.append(("query/indexed_serve", dt_idx * 1e6 / NUM_QUERIES,
                 f"qps={qps_idx:.1f} p50_ms={np.percentile(lat_idx, 50) * 1e3:.1f} "
                 f"p99_ms={np.percentile(lat_idx, 99) * 1e3:.1f}"))

    # sharded-slab serving: same scheduler, per-shard blocks, no slab
    # reassembly (host-loop dispatch on this 1-device bench; 4·n·R/S bytes
    # of slab resident per wave call instead of 4·n·R).
    sharded = shard_walk_index(index, NUM_SHARDS)
    sched_sh = QueryScheduler(g, sharded, max_walks=16_384, max_queries=12,
                              max_steps=plan.num_steps)

    def serve_sharded():
        for r in _requests():
            sched_sh.submit(r)
        out = sched_sh.run()
        sched_sh.finished = []
        return out

    serve_sharded()                                  # warm the wave programs
    t0 = time.perf_counter()
    results_sh = serve_sharded()
    dt_sh = time.perf_counter() - t0
    lat_sh = np.asarray([r.latency_s for r in results_sh])
    qps_sh = NUM_QUERIES / dt_sh
    slab_mb = index.endpoints.nbytes / 1e6
    rows.append(("query/query_serving_sharded", dt_sh * 1e6 / NUM_QUERIES,
                 f"qps={qps_sh:.1f} p50_ms={np.percentile(lat_sh, 50) * 1e3:.1f} "
                 f"p99_ms={np.percentile(lat_sh, 99) * 1e3:.1f} "
                 f"shards={NUM_SHARDS} slab_mb_per_shard="
                 f"{slab_mb / NUM_SHARDS:.2f} dispatch="
                 f"{'mesh' if sched_sh.runtime.is_mesh else 'host_loop'}"))

    t0 = time.perf_counter()
    lat_rst = _restart_latencies(g, plan, _requests())
    dt_rst = time.perf_counter() - t0
    qps_rst = NUM_QUERIES / dt_rst
    rows.append(("query/restart_serve", dt_rst * 1e6 / NUM_QUERIES,
                 f"qps={qps_rst:.1f} p50_ms={np.percentile(lat_rst, 50) * 1e3:.1f} "
                 f"p99_ms={np.percentile(lat_rst, 99) * 1e3:.1f}"))

    speedup = qps_idx / qps_rst
    rows.append(("query/indexed_vs_restart", 0.0,
                 f"speedup={speedup:.2f}x walks/query={plan.num_walks} "
                 f"t={plan.num_steps} rounds={plan.num_rounds(icfg.segment_len)}"))
    emit(rows)
    emit_json("query", rows, extra={
        "num_queries": NUM_QUERIES,
        "epsilon": EPSILON, "delta": DELTA, "k": K,
        "qps_indexed": round(qps_idx, 2),
        "qps_sharded": round(qps_sh, 2),
        "qps_restart": round(qps_rst, 2),
        "p50_ms_indexed": round(float(np.percentile(lat_idx, 50)) * 1e3, 2),
        "p99_ms_indexed": round(float(np.percentile(lat_idx, 99)) * 1e3, 2),
        "p50_ms_sharded": round(float(np.percentile(lat_sh, 50)) * 1e3, 2),
        "p99_ms_sharded": round(float(np.percentile(lat_sh, 99)) * 1e3, 2),
        "p50_ms_restart": round(float(np.percentile(lat_rst, 50)) * 1e3, 2),
        "p99_ms_restart": round(float(np.percentile(lat_rst, 99)) * 1e3, 2),
        "index_build_s": round(build_s, 3),
        "num_shards": NUM_SHARDS,
        "slab_mb_per_shard": round(slab_mb / NUM_SHARDS, 3),
        "speedup": round(speedup, 2),
        "sharded_vs_gathered": round(qps_sh / qps_idx, 3),
    })


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny gathered-vs-sharded serving equivalence "
                         "sweep; no timing, no JSON rewrite")
    if ap.parse_args().smoke:
        smoke()
    else:
        main()
