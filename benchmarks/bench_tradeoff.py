"""Paper Figures 3/4 — accuracy vs running time vs network bytes.

Sweeps iterations t and p_s at fixed N=800k, reporting (time, bytes,
mass@100) triples — the tradeoff frontier the paper plots as circles.
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_graph, bench_pi, emit, timeit
from repro.core import FrogWildConfig, frogwild, frogwild_run, normalized_mass_captured
from repro.engine.netcost import frogwild_bytes_model


def main():
    g = bench_graph()
    pi = bench_pi()
    rows = []
    for t in (2, 4, 8):
        for p_s in (1.0, 0.4):
            cfg = FrogWildConfig(num_frogs=800_000, num_steps=t, p_s=p_s,
                                 erasure="channel", num_shards=20)
            fn = jax.jit(lambda k, c=cfg: frogwild_run(g, c, k).counts)
            us = timeit(lambda: fn(jax.random.PRNGKey(0)), repeats=1)
            res = frogwild(g, cfg, seed=0)
            m = float(normalized_mass_captured(res.pi_hat, pi, 100))
            by = frogwild_bytes_model(800_000, t, 0.15, p_s, 20).total
            rows.append((f"fig3/t{t}_ps{p_s}", us,
                         f"mass100={m:.4f} bytes_MB={by/1e6:.2f}"))
    return emit(rows)


if __name__ == "__main__":
    main()
