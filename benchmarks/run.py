"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
  PYTHONPATH=src python -m benchmarks.run [--only fig2]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    bench_accuracy_topk,
    bench_iteration_cost,
    bench_kernels,
    bench_network,
    bench_query,
    bench_sparsify,
    bench_theory,
    bench_tradeoff,
    bench_walkers,
)

ALL = {
    "fig1_iteration_cost": bench_iteration_cost,
    "fig2_accuracy_topk": bench_accuracy_topk,
    "fig3_tradeoff": bench_tradeoff,
    "fig5_sparsify": bench_sparsify,
    "fig6_walkers": bench_walkers,
    "fig8_network": bench_network,
    "thm1_theory": bench_theory,
    "kernels": bench_kernels,
    "query_serving": bench_query,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on bench name")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, mod in ALL.items():
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        mod.main()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
