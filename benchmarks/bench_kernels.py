"""Kernel-level microbench: CPU wall time of the jnp reference paths (the
Pallas kernels are TPU-target; interpret mode is correctness-only) plus the
analytic FLOPs each kernel's tile schedule would execute.

Emits ``BENCH_kernels.json`` (via benchmarks.common.emit_json) so the perf
trajectory stays machine-readable across PRs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json, timeit
from repro.graph import chung_lu_powerlaw, to_ell
from repro.kernels import ops


def main():
    rows = []
    g = chung_lu_powerlaw(n=16_384, avg_out_deg=12, seed=0)
    ell = to_ell(g, K=16)
    x = jnp.ones((ell.n_rows,), jnp.float32)
    spmv = jax.jit(lambda v: ops.spmv(ell, v, impl="ref"))
    us = timeit(lambda: spmv(x))
    rows.append(("kernel/spmv_ref_n16k", us,
                 f"nnz={g.nnz} spill={ell.spill_nnz}"))

    dest = jnp.asarray(np.random.default_rng(0).integers(0, 4096, 100_000),
                       dtype=jnp.int32)
    fc = jax.jit(lambda d: ops.frog_count(d, 4096, impl="ref"))
    us_ref = timeit(lambda: fc(dest))
    rows.append(("kernel/frog_count_ref_100k", us_ref, "bins=4096"))
    fcs = jax.jit(lambda d: ops.frog_count(d, 4096, impl="sort"))
    us_sort = timeit(lambda: fcs(dest))
    rows.append(("kernel/frog_count_sort_100k", us_sort,
                 f"bins=4096 work=(N+n)logN vs_onehot=N*n/512 "
                 f"speedup_vs_ref={us_ref / max(us_sort, 1):.2f}x"))

    # fused walker step: jnp oracle wall time + the fused kernel's work model
    # (the Pallas kernel itself runs in interpret mode here — correctness
    # only; its compiled profile is the TPU target).
    N = 100_000
    rng = np.random.default_rng(1)
    pos = jnp.asarray(rng.integers(0, g.n, N), jnp.int32)
    die = jnp.asarray(rng.random(N) < 0.15, jnp.int32)
    bits = jnp.asarray(rng.integers(0, 1 << 30, N), jnp.int32)
    fs = jax.jit(lambda p, d, b: ops.frog_step(
        p, d, b, g.row_ptr, g.col_idx, g.out_deg, g.n, impl="ref"))
    us_step = timeit(lambda: fs(pos, die, bits))
    rows.append(("kernel/frog_step_ref_100k", us_step,
                 f"n={g.n} fused=gather+draw+gather+tally"))

    B, Hq, Hkv, S, D = 1, 8, 2, 2048, 64
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    att = jax.jit(lambda a, b, c: ops.attention(a, b, c, causal=True,
                                                impl="jnp_flash"))
    us = timeit(lambda: att(q, k, v), repeats=1)
    flops = 4 * B * Hq * S * S * D / 2
    rows.append(("kernel/flash_jnp_2k", us, f"flops={flops:.2e}"))
    att_w = jax.jit(lambda a, b, c: ops.attention(
        a, b, c, causal=True, window=256, impl="jnp_flash"))
    us_w = timeit(lambda: att_w(q, k, v), repeats=1)
    rows.append(("kernel/flash_jnp_2k_window256", us_w,
                 f"banded_speedup={us / max(us_w, 1):.2f}x"))
    emit(rows)
    emit_json("kernels", rows)
    return rows


if __name__ == "__main__":
    main()
