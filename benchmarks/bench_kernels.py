"""Kernel-level microbench: CPU wall time of the jnp reference paths (the
Pallas kernels are TPU-target; interpret mode is correctness-only) plus the
analytic FLOPs each kernel's tile schedule would execute.

The ``frog_step_stream`` rows compare the resident and HBM-streaming fused
kernels *in interpret mode at equal sizes* — a schedule-level comparison
(grid steps × per-step work), not a TPU wall-time claim — and check the
streamed kernel's byte-for-byte equivalence at a size whose graph block
exceeds the resident kernel's VMEM budget.

Emits ``BENCH_kernels.json`` (via benchmarks.common.emit_json) so the perf
trajectory stays machine-readable across PRs.

``--smoke`` runs every dispatch path at tiny sizes and asserts equivalence
against the oracles — no timing, no JSON rewrite; wired into
``scripts/ci_tier1.sh --bench-smoke`` so a broken kernel dispatch fails
tier-1 instead of surfacing only in bench runs.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_json, timeit
from repro.graph import chung_lu_powerlaw, to_ell
from repro.kernels import ops


def _step_inputs(n, N, seed):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.integers(0, n, N), jnp.int32),
            jnp.asarray(rng.random(N) < 0.15, jnp.int32),
            jnp.asarray(rng.integers(0, 1 << 30, N), jnp.int32))


def _assert_step_equal(got, want, tag):
    for a, b in zip(got, want):
        assert (np.asarray(a) == np.asarray(b)).all(), tag


def smoke():
    """Tiny-size dispatch sweep: every impl flag must agree with its oracle.

    Exercised by ``scripts/ci_tier1.sh --bench-smoke``; any mismatch or
    dispatch error exits nonzero and fails tier-1.
    """
    g = chung_lu_powerlaw(n=384, avg_out_deg=6, seed=0)
    pos, die, bits = _step_inputs(g.n, 600, 1)
    want = ops.frog_step(pos, die, bits, g.row_ptr, g.col_idx, g.out_deg,
                         g.n, impl="ref")
    for impl, kw in [("pallas", {}), ("stream", {}),
                     ("auto", dict(vmem_budget=1024)),
                     ("auto", dict(vmem_budget=1 << 30))]:
        got = ops.frog_step(pos, die, bits, g.row_ptr, g.col_idx, g.out_deg,
                            g.n, impl=impl, vertex_block=128, frog_block=256,
                            **kw)
        _assert_step_equal(got, want, (impl, kw))
        print(f"smoke frog_step impl={impl} {kw or ''} OK")
    dest = jnp.asarray(np.random.default_rng(2).integers(0, g.n, 900),
                       jnp.int32)
    cwant = np.asarray(ops.frog_count(dest, g.n, impl="ref"))
    for impl, kw in [("pallas", {}), ("sort", {}), ("auto", {}),
                     ("sort", dict(assume_sorted=True))]:
        d = jnp.sort(dest) if kw.get("assume_sorted") else dest
        got = np.asarray(ops.frog_count(d, g.n, impl=impl, **kw))
        assert (got == cwant).all(), (impl, kw)
        print(f"smoke frog_count impl={impl} {kw or ''} OK")

    # stitch dispatch: global kernel vs oracle, and the per-shard
    # local-index variant composing (sum over shards) to the global result.
    rng = np.random.default_rng(3)
    W, R, S = 600, 5, 4
    spos = jnp.asarray(rng.integers(0, g.n, W), jnp.int32)
    sstop = jnp.asarray(rng.integers(0, 2, W), jnp.int32)
    sbits = jnp.asarray(rng.integers(0, 1 << 30, W), jnp.int32)
    endpoints = jnp.asarray(rng.integers(0, g.n, (g.n, R)), jnp.int32)
    sw = ops.stitch_step(spos, sstop, sbits, endpoints, g.n, impl="ref")
    got = ops.stitch_step(spos, sstop, sbits, endpoints, g.n, impl="pallas")
    _assert_step_equal(got, sw, "stitch pallas")
    print("smoke stitch_step impl=pallas OK")
    sz = g.n // S
    for impl in ("pallas", "ref"):
        acc_n = jnp.zeros_like(spos)
        acc_c = []
        for s in range(S):
            nl, cl = ops.stitch_step_local(
                spos, sstop, sbits, endpoints[s * sz:(s + 1) * sz],
                s * sz, impl=impl)
            acc_n = acc_n + nl
            acc_c.append(np.asarray(cl))
        assert (np.asarray(acc_n) == np.asarray(sw[0])).all(), impl
        assert (np.concatenate(acc_c) == np.asarray(sw[1])).all(), impl
        print(f"smoke stitch_step_local impl={impl} composes OK")
    print("smoke OK: kernel dispatch paths all agree with oracles")


def main():
    rows = []
    g = chung_lu_powerlaw(n=16_384, avg_out_deg=12, seed=0)
    ell = to_ell(g, K=16)
    x = jnp.ones((ell.n_rows,), jnp.float32)
    spmv = jax.jit(lambda v: ops.spmv(ell, v, impl="ref"))
    us = timeit(lambda: spmv(x))
    rows.append(("kernel/spmv_ref_n16k", us,
                 f"nnz={g.nnz} spill={ell.spill_nnz}"))

    dest = jnp.asarray(np.random.default_rng(0).integers(0, 4096, 100_000),
                       dtype=jnp.int32)
    fc = jax.jit(lambda d: ops.frog_count(d, 4096, impl="ref"))
    us_ref = timeit(lambda: fc(dest))
    rows.append(("kernel/frog_count_ref_100k", us_ref, "bins=4096"))
    fcs = jax.jit(lambda d: ops.frog_count(d, 4096, impl="sort"))
    us_sort = timeit(lambda: fcs(dest))
    rows.append(("kernel/frog_count_sort_100k", us_sort,
                 f"bins=4096 work=(N+n)logN vs_onehot=N*n/512 "
                 f"speedup_vs_ref={us_ref / max(us_sort, 1):.2f}x"))
    # presorted fast path: the sort is the dominant term above — callers
    # that already hold sorted destinations (the streamed superstep) pay
    # only the searchsorted pass.
    dest_sorted = jnp.sort(dest)
    fcp = jax.jit(lambda d: ops.frog_count(d, 4096, impl="sort",
                                           assume_sorted=True))
    us_pre = timeit(lambda: fcp(dest_sorted))
    rows.append(("kernel/frog_count_sort_presorted_100k", us_pre,
                 f"bins=4096 work=n*logN "
                 f"speedup_vs_sort={us_sort / max(us_pre, 1):.2f}x "
                 f"speedup_vs_ref={us_ref / max(us_pre, 1):.2f}x"))

    # fused walker step: jnp oracle wall time + the fused kernel's work model
    # (the Pallas kernel itself runs in interpret mode here — correctness
    # only; its compiled profile is the TPU target).
    N = 100_000
    rng = np.random.default_rng(1)
    pos = jnp.asarray(rng.integers(0, g.n, N), jnp.int32)
    die = jnp.asarray(rng.random(N) < 0.15, jnp.int32)
    bits = jnp.asarray(rng.integers(0, 1 << 30, N), jnp.int32)
    fs = jax.jit(lambda p, d, b: ops.frog_step(
        p, d, b, g.row_ptr, g.col_idx, g.out_deg, g.n, impl="ref"))
    us_step = timeit(lambda: fs(pos, die, bits))
    rows.append(("kernel/frog_step_ref_100k", us_step,
                 f"n={g.n} fused=gather+draw+gather+tally"))

    # resident vs HBM-streaming fused kernel, interpret mode at equal size:
    # a schedule-level comparison (grid steps × per-step work — the thing
    # interpret mode faithfully reproduces), not TPU wall time.
    ns, Ns, bv, fb = 4096, 8192, 512, 1024
    gs = chung_lu_powerlaw(n=ns, avg_out_deg=12, seed=3)
    sp, sd, sb = _step_inputs(ns, Ns, 4)
    res_fn = jax.jit(lambda p, d, b: ops.frog_step(
        p, d, b, gs.row_ptr, gs.col_idx, gs.out_deg, ns, impl="pallas",
        vertex_block=bv, frog_block=fb))
    stream_fn = jax.jit(lambda p, d, b: ops.frog_step(
        p, d, b, gs.row_ptr, gs.col_idx, gs.out_deg, ns, impl="stream",
        vertex_block=bv, frog_block=fb))
    want = ops.frog_step(sp, sd, sb, gs.row_ptr, gs.col_idx, gs.out_deg,
                         ns, impl="ref")
    _assert_step_equal(res_fn(sp, sd, sb), want, "resident")
    _assert_step_equal(stream_fn(sp, sd, sb), want, "stream")
    us_res = timeit(lambda: res_fn(sp, sd, sb))
    us_stream = timeit(lambda: stream_fn(sp, sd, sb))
    grid_res = (ns // bv) * (Ns // fb)
    grid_stream = (Ns + (ns // bv) * (fb - 1) + fb - 1) // fb
    rows.append(("kernel/frog_step_resident_interp_n4k", us_res,
                 f"N={Ns} grid_steps={grid_res} "
                 f"vmem_graph_bytes={ops.resident_graph_bytes(ns, gs.nnz)}"))
    rows.append((
        "kernel/frog_step_stream_interp_n4k", us_stream,
        f"N={Ns} grid_steps<={grid_stream} equiv=pass "
        f"ratio_vs_resident={us_stream / max(us_res, 1):.2f}x "
        f"vmem_working_set=4*(3*{bv}+E_blk+5*{fb})"))

    # streamed kernel past the resident VMEM budget: the bench graph's CSR
    # block (4.3 MB) exceeds a 4 MB budget, so impl="auto" must route to
    # the streamed kernel — and stay byte-for-byte the oracle.
    from benchmarks.common import bench_graph
    gl = bench_graph()                   # n=65536, nnz≈942k
    budget = 4 * 1024 * 1024
    assert ops.resident_graph_bytes(gl.n, gl.nnz) > budget
    lp, ld, lb = _step_inputs(gl.n, 16_384, 5)
    big_fn = jax.jit(lambda p, d, b: ops.frog_step(
        p, d, b, gl.row_ptr, gl.col_idx, gl.out_deg, gl.n, impl="auto",
        vmem_budget=budget, vertex_block=4096, frog_block=2048))
    want = ops.frog_step(lp, ld, lb, gl.row_ptr, gl.col_idx, gl.out_deg,
                         gl.n, impl="ref")
    _assert_step_equal(big_fn(lp, ld, lb), want, "stream-over-budget")
    us_big = timeit(lambda: big_fn(lp, ld, lb))
    rows.append((
        "kernel/frog_step_stream_interp_n64k_over_budget", us_big,
        f"N=16384 auto->stream equiv=pass "
        f"graph_bytes={ops.resident_graph_bytes(gl.n, gl.nnz)}"
        f">budget={budget} hbm_streams_each_slab_once=true"))

    B, Hq, Hkv, S, D = 1, 8, 2, 2048, 64
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    att = jax.jit(lambda a, b, c: ops.attention(a, b, c, causal=True,
                                                impl="jnp_flash"))
    us = timeit(lambda: att(q, k, v), repeats=1)
    flops = 4 * B * Hq * S * S * D / 2
    rows.append(("kernel/flash_jnp_2k", us, f"flops={flops:.2e}"))
    att_w = jax.jit(lambda a, b, c: ops.attention(
        a, b, c, causal=True, window=256, impl="jnp_flash"))
    us_w = timeit(lambda: att_w(q, k, v), repeats=1)
    rows.append(("kernel/flash_jnp_2k_window256", us_w,
                 f"banded_speedup={us / max(us_w, 1):.2f}x"))
    emit(rows)
    emit_json("kernels", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-size dispatch equivalence sweep; no timing, "
                         "no BENCH_kernels.json rewrite")
    if ap.parse_args().smoke:
        smoke()
    else:
        main()
