"""Shared benchmark utilities: cached graphs, timing, CSV rows, JSON dumps.

Every bench emits ``name,us_per_call,derived`` rows (run.py prints them).
Benches that track the perf trajectory across PRs additionally call
``emit_json`` to write a machine-readable ``BENCH_<tag>.json`` at the repo
root (bench_kernels → BENCH_kernels.json, bench_iteration_cost →
BENCH_iteration.json).
Graph scale is CPU-sized (LiveJournal stand-in: 65k vertices / ~1M edges);
the full-scale numbers live in the dry-run/roofline tables.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Callable, List, Optional, Tuple

import jax

from repro.configs.frogwild_graphs import LIVEJOURNAL_BENCH
from repro.core import power_iteration
from repro.graph import chung_lu_powerlaw

Row = Tuple[str, float, str]


@functools.lru_cache(maxsize=2)
def bench_graph(n: int = LIVEJOURNAL_BENCH.n):
    return chung_lu_powerlaw(
        n=n, avg_out_deg=LIVEJOURNAL_BENCH.avg_out_deg,
        theta=LIVEJOURNAL_BENCH.theta, seed=LIVEJOURNAL_BENCH.seed)


@functools.lru_cache(maxsize=2)
def bench_pi(n: int = LIVEJOURNAL_BENCH.n):
    return power_iteration(bench_graph(n), num_iters=60)


def timeit(fn: Callable, repeats: int = 3) -> float:
    """Median wall time (µs) of ``fn()`` with ready-blocking."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(rows: List[Row]) -> List[Row]:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def emit_json(tag: str, rows: List[Row], extra: Optional[dict] = None) -> str:
    """Writes ``BENCH_<tag>.json`` at the repo root and returns its path.

    Schema: ``{"bench": tag, "rows": [{name, us, derived}, ...], "extra":
    {...}}`` — stable keys so future PRs can diff the perf trajectory
    mechanically.
    """
    payload = {
        "bench": tag,
        "rows": [
            {"name": name, "us": round(float(us), 2), "derived": derived}
            for name, us, derived in rows
        ],
    }
    if extra:
        payload["extra"] = extra
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, f"BENCH_{tag}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")
    return path
