"""Paper Figure 6 — accuracy & time vs number of walkers N and iterations t.

Paper finding: 800K frogs / 4 iterations is the sweet spot on BOTH
LiveJournal and Twitter (slow N growth with graph size — Remark 6).
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_graph, bench_pi, emit, timeit
from repro.core import (FrogWildConfig, frogwild, frogwild_run,
                        normalized_mass_captured)


def main():
    g = bench_graph()
    pi = bench_pi()
    rows = []
    for N in (50_000, 200_000, 800_000):
        cfg = FrogWildConfig(num_frogs=N, num_steps=4, p_s=1.0)
        res = frogwild(g, cfg, seed=0)
        m = float(normalized_mass_captured(res.pi_hat, pi, 100))
        fn = jax.jit(lambda k, c=cfg: frogwild_run(g, c, k).counts)
        us = timeit(lambda: fn(jax.random.PRNGKey(0)), repeats=1)
        rows.append((f"fig6/N{N}_t4", us, f"mass100={m:.4f}"))
    for t in (1, 2, 4, 8):
        cfg = FrogWildConfig(num_frogs=800_000, num_steps=t, p_s=1.0)
        res = frogwild(g, cfg, seed=0)
        m = float(normalized_mass_captured(res.pi_hat, pi, 100))
        rows.append((f"fig6/N800000_t{t}", 0.0, f"mass100={m:.4f}"))
    return emit(rows)


if __name__ == "__main__":
    main()
