"""Paper Figure 2 — mass captured & exact identification vs k, per p_s.

Paper finding: p_s ∈ {1, 0.7} beats 1-iteration GraphLab PR everywhere;
p_s = 0.4 is "relatively good"; p_s = 0.1 "reasonable" on mass captured.
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_graph, bench_pi, emit, timeit
from repro.core import (
    FrogWildConfig,
    exact_identification,
    frogwild,
    normalized_mass_captured,
    reduced_iteration_baseline,
)


def main():
    g = bench_graph()
    pi = bench_pi()
    rows = []
    for p_s in (1.0, 0.7, 0.4, 0.1):
        cfg = FrogWildConfig(num_frogs=800_000, num_steps=4, p_s=p_s,
                             erasure="channel", num_shards=20)
        res = frogwild(g, cfg, seed=0)
        for k in (10, 100, 300):
            m = float(normalized_mass_captured(res.pi_hat, pi, k))
            e = float(exact_identification(res.pi_hat, pi, k))
            rows.append((f"fig2/ps{p_s}_k{k}", 0.0,
                         f"mass={m:.4f} exact={e:.4f}"))
    pr1 = reduced_iteration_baseline(g, num_iters=1)
    for k in (10, 100, 300):
        m = float(normalized_mass_captured(pr1, pi, k))
        e = float(exact_identification(pr1, pi, k))
        rows.append((f"fig2/graphlab_pr_1iter_k{k}", 0.0,
                     f"mass={m:.4f} exact={e:.4f}"))
    return emit(rows)


if __name__ == "__main__":
    main()
