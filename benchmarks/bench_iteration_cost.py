"""Paper Figure 1 — per-iteration/total cost: FrogWild vs GraphLab-PR.

The paper reports <1 s/iter for FrogWild vs ~7.5 s/iter for GraphLab PR on
Twitter (7× speedup) plus ~1000× network reduction. Here: wall time per
superstep of the walker process (O(alive frogs) work) vs one power iteration
(O(E) work), on the LiveJournal-scale stand-in, plus modeled wire bytes.
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_graph, emit, timeit
from repro.core import FrogWildConfig, frogwild_run, power_iteration
from repro.engine.netcost import frogwild_bytes_model, pagerank_bytes_model


def main():
    g = bench_graph()
    N, t = 800_000, 4

    cfg = FrogWildConfig(num_frogs=N, num_steps=t, p_s=1.0)
    fw = jax.jit(lambda k: frogwild_run(g, cfg, k).counts)
    fw_us = timeit(lambda: fw(jax.random.PRNGKey(0)))

    pr1 = jax.jit(lambda: power_iteration(g, num_iters=1))
    pr_us = timeit(pr1)
    pr2_us = timeit(jax.jit(lambda: power_iteration(g, num_iters=2)))

    fw_bytes = frogwild_bytes_model(N, t, 0.15, 0.7, 20).total
    pr_bytes = pagerank_bytes_model(g.n, 2, 20).total

    rows = [
        (f"fig1/frogwild_total_t{t}_N{N}", fw_us,
         f"per_iter_us={fw_us / t:.0f}"),
        ("fig1/graphlab_pr_1iter", pr_us, f"edges={g.nnz}"),
        ("fig1/graphlab_pr_2iter", pr2_us,
         f"speedup_vs_frogwild={pr2_us / fw_us:.2f}x"),
        ("fig1/net_bytes_frogwild_ps0.7", fw_bytes / 1e6,
         "unit=MB(model,20shards)"),
        ("fig1/net_bytes_graphlab_2iter", pr_bytes / 1e6,
         f"ratio={pr_bytes / fw_bytes:.1f}x"),
    ]
    return emit(rows)


if __name__ == "__main__":
    main()
