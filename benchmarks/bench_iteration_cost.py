"""Paper Figure 1 — per-iteration/total cost: FrogWild vs GraphLab-PR,
plus the erasure-superstep cost model (rejection vs cumsum draw).

The paper reports <1 s/iter for FrogWild vs ~7.5 s/iter for GraphLab PR on
Twitter (7× speedup) plus ~1000× network reduction. Here: wall time per
superstep of the walker process (O(alive frogs) work) vs one power iteration
(O(E) work), on the LiveJournal-scale stand-in, plus modeled wire bytes.

The ``era/`` section measures the blocking-walk scatter draw in isolation —
the rejection-sampled O(N · 1/p_s) path vs the O(nnz) cumsum/searchsorted
reference — at the paper's frog density (N ≈ 2–3 % of n: the paper runs 800k
frogs on the 41.6M-vertex Twitter graph; scaled to this 65k-vertex bench
graph that is ~2k frogs), plus a 4×-denser point to show the crossover
behaviour, and cross-checks that top-k mass-captured accuracy (Definition 6
metric) is within sampling noise between the two draws.

Emits ``BENCH_iteration.json`` (via benchmarks.common.emit_json) so the perf
trajectory stays machine-readable across PRs.
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_graph, bench_pi, emit, emit_json, timeit
from repro.core import (FrogWildConfig, frogwild_run, normalized_mass_captured,
                        power_iteration)
from repro.core.frogwild import draw_next
from repro.engine.netcost import frogwild_bytes_model, pagerank_bytes_model

ERA_PS = (0.1, 0.3, 0.7)
ERA_N = (2048, 8192)          # paper-scaled frog count + 4×-denser point


def bench_erasure_superstep(g, rows, extra):
    key = jax.random.PRNGKey(0)
    for N in ERA_N:
        pos = jax.random.randint(key, (N,), 0, g.n, dtype=jax.numpy.int32)
        for p_s in ERA_PS:
            us = {}
            for draw in ("rejection", "cumsum"):
                cfg = FrogWildConfig(p_s=p_s, erasure="channel",
                                     num_shards=20, draw=draw)
                fn = jax.jit(lambda k, c=cfg: draw_next(g, c, k, pos))
                fn(key)                                   # compile
                us[draw] = timeit(lambda: fn(key), repeats=9)
            speedup = us["cumsum"] / us["rejection"]
            probes = N * 20          # channel model: N · S coin probes
            rows.append((
                f"era/draw_N{N}_ps{p_s}", us["rejection"],
                f"cumsum_us={us['cumsum']:.0f} speedup={speedup:.2f}x "
                f"work_probes<={probes} work_edges={g.nnz}",
            ))
            extra.setdefault("erasure_speedup", {})[f"N{N}_ps{p_s}"] = round(
                speedup, 2
            )


def bench_erasure_accuracy(g, pi, extra):
    """Top-k mass captured must agree between draws up to sampling noise."""
    k = 50
    for p_s in ERA_PS:
        masses = {}
        for draw in ("rejection", "cumsum"):
            vals = []
            for seed in (0, 1):
                cfg = FrogWildConfig(num_frogs=100_000, num_steps=8, p_s=p_s,
                                     erasure="channel", num_shards=20,
                                     draw=draw)
                fn = jax.jit(lambda kk, c=cfg: frogwild_run(g, c, kk).pi_hat)
                pi_hat = fn(jax.random.PRNGKey(seed))
                vals.append(float(normalized_mass_captured(pi_hat, pi, k)))
            masses[draw] = vals
        extra.setdefault("erasure_accuracy_mass50", {})[f"ps{p_s}"] = {
            "rejection": [round(v, 4) for v in masses["rejection"]],
            "cumsum": [round(v, 4) for v in masses["cumsum"]],
        }


def main():
    g = bench_graph()
    N, t = 800_000, 4

    cfg = FrogWildConfig(num_frogs=N, num_steps=t, p_s=1.0)
    fw = jax.jit(lambda k: frogwild_run(g, cfg, k).counts)
    fw_us = timeit(lambda: fw(jax.random.PRNGKey(0)))

    pr1 = jax.jit(lambda: power_iteration(g, num_iters=1))
    pr_us = timeit(pr1)
    pr2_us = timeit(jax.jit(lambda: power_iteration(g, num_iters=2)))

    fw_bytes = frogwild_bytes_model(N, t, 0.15, 0.7, 20).total
    pr_bytes = pagerank_bytes_model(g.n, 2, 20).total

    rows = [
        (f"fig1/frogwild_total_t{t}_N{N}", fw_us,
         f"per_iter_us={fw_us / t:.0f}"),
        ("fig1/graphlab_pr_1iter", pr_us, f"edges={g.nnz}"),
        ("fig1/graphlab_pr_2iter", pr2_us,
         f"speedup_vs_frogwild={pr2_us / fw_us:.2f}x"),
        ("fig1/net_bytes_frogwild_ps0.7", fw_bytes / 1e6,
         "unit=MB(model,20shards)"),
        ("fig1/net_bytes_graphlab_2iter", pr_bytes / 1e6,
         f"ratio={pr_bytes / fw_bytes:.1f}x"),
    ]
    extra = {"graph": {"n": g.n, "nnz": g.nnz}}
    bench_erasure_superstep(g, rows, extra)
    bench_erasure_accuracy(g, bench_pi(), extra)
    emit(rows)
    emit_json("iteration", rows, extra)
    return rows


if __name__ == "__main__":
    main()
