#!/usr/bin/env bash
# Canonical tier-1 verification — the one command builders and CI invoke.
# Extra pytest args pass through, e.g. scripts/ci_tier1.sh -k query
# --bench-smoke additionally runs (1) the service-API gate — the API-surface
# snapshot (tests/test_api_surface.py) plus the facade/shim byte-compat and
# QueryHandle anytime tests (tests/test_service_api.py) and the gateway
# contract tests (tests/test_gateway.py: cache dominance, in-flight dedup,
# replica routing, structured rejection) — and (2) the dispatch equivalence
# sweeps (benchmarks/bench_kernels.py --smoke: every kernel impl= path
# incl. the stitch/local-stitch variants; benchmarks/bench_query.py
# --smoke: the fused-dispatch equivalence gate — gathered vs fused
# single-dispatch sharded vs legacy host-loop sharded vs handle-driven
# serving, byte-identical answers at tiny sizes — the AOT-ladder
# recompile-count gate (zero wave retraces across a mixed topk/PPR
# sweep after warm_ladder), the handle-mode overhead gate, the
# fault-injection sweep — supervised zero-fault byte-identity and seeded
# shard-loss degradation with the Theorem-1-widened bound — and the
# 2-replica gateway sweeps: cold-miss byte-equivalence to a direct
# service plus dominated cache hits with zero new walks, and the seeded
# gateway fault sweep — replica crash mid-query -> failover answer
# byte-identical to the fault-free run with the sick replica
# quarantined then restarted over the same shared slab, stall ->
# quarantine + reroute, overload -> structured shed with Retry-After —
# and the incremental-refresh gate (PR 10): a 1%-window mutation
# invalidates only a small fraction of segments, the refreshed slab is
# byte-identical to a full rebuild at the new epoch, and an in-flight
# query spanning the epoch commit finishes byte-identically to a
# never-mutated service; tiny sizes, no BENCH json rewrite) so a broken
# dispatch, surface, cache, degradation, failover, or refresh change
# fails tier-1 instead of only bench runs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BENCH_SMOKE=0
args=()
for a in "$@"; do
  if [[ "$a" == "--bench-smoke" ]]; then
    BENCH_SMOKE=1
  else
    args+=("$a")
  fi
done

python -m pytest -x -q ${args[@]+"${args[@]}"}

if [[ "$BENCH_SMOKE" == 1 ]]; then
  # service smoke: API-surface snapshot + facade/shim byte-compat gate.
  # The unfiltered full-suite run above already collects these files, so
  # only re-run them explicitly when pass-through args may have filtered
  # them out of the main run.
  if [[ ${#args[@]} -gt 0 ]]; then
    python -m pytest -q tests/test_api_surface.py tests/test_service_api.py \
      tests/test_gateway.py
  fi
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_kernels.py --smoke
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_query.py --smoke
fi
