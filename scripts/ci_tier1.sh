#!/usr/bin/env bash
# Canonical tier-1 verification — the one command builders and CI invoke.
# Extra pytest args pass through, e.g. scripts/ci_tier1.sh -k query
# --bench-smoke additionally runs the dispatch equivalence sweeps
# (benchmarks/bench_kernels.py --smoke: every kernel impl= path incl. the
# stitch/local-stitch variants; benchmarks/bench_query.py --smoke: gathered
# vs sharded-slab serving — tiny sizes, no BENCH json rewrite) so a broken
# dispatch fails tier-1 instead of only bench runs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BENCH_SMOKE=0
args=()
for a in "$@"; do
  if [[ "$a" == "--bench-smoke" ]]; then
    BENCH_SMOKE=1
  else
    args+=("$a")
  fi
done

python -m pytest -x -q ${args[@]+"${args[@]}"}

if [[ "$BENCH_SMOKE" == 1 ]]; then
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_kernels.py --smoke
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_query.py --smoke
fi
