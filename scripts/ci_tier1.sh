#!/usr/bin/env bash
# Canonical tier-1 verification — the one command builders and CI invoke.
# Extra pytest args pass through, e.g. scripts/ci_tier1.sh -k query
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
