"""Quickstart: approximate the top-k PageRank of a power-law graph through
the FrogWildService facade and compare against exact power iteration.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import FrogWildService, RuntimeConfig, ShardConfig
from repro.core import (exact_identification, normalized_mass_captured,
                        power_iteration, theory)
from repro.graph import chung_lu_powerlaw


def main():
    print("Generating a 50k-vertex power-law graph (θ=2.2)…")
    g = chung_lu_powerlaw(n=50_000, avg_out_deg=12, seed=0)
    print(f"  n={g.n} edges={g.nnz}")

    print("Exact PageRank (50 power iterations — the expensive way)…")
    pi = power_iteration(g, num_iters=50)

    k = 20
    # Remark 6: pick t and N from the analytic scaling
    _, idx = jax.lax.top_k(pi, k)
    mu_k = float(pi[idx].sum())
    t = theory.suggested_steps(mu_k)
    print(f"FrogWild!: N=400k frogs, t={t} steps, p_s=0.7 "
          f"(partial synchronization)…")
    svc = FrogWildService.open(g, RuntimeConfig(
        num_frogs=400_000, num_steps=t, p_s=0.7, erasure="channel",
        runtime=ShardConfig(num_shards=16)))
    res = svc.pagerank(seed=0)

    mass = float(normalized_mass_captured(res.pi_hat, pi, k))
    exact = float(exact_identification(res.pi_hat, pi, k))
    print(f"  mass captured @ top-{k}:      {mass:.4f}")
    print(f"  exact identification @ {k}:   {exact:.3f}")
    _, top = jax.lax.top_k(res.pi_hat, 10)
    print(f"  estimated top-10 vertices: {list(map(int, top))}")
    _, true_top = jax.lax.top_k(pi, 10)
    print(f"  true      top-10 vertices: {list(map(int, true_top))}")


if __name__ == "__main__":
    main()
