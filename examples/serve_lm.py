"""Serve a small LM with batched requests through the fixed-slot scheduler.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", args.arch, "--smoke",
           "--requests", str(args.requests)]
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
