"""Mutate-while-serving demo (dynamic graphs, PR 10): a gateway keeps
answering top-k queries while a stream of edge-mutation batches lands —
each batch compacts a new CSR epoch, incrementally refreshes only the
invalidated walk segments, and orphans stale cached certificates, all
without interrupting in-flight queries.

  PYTHONPATH=src python examples/mutate_while_serving.py
"""
import time

import numpy as np

from repro import Gateway, RuntimeConfig, ServingConfig, ShardConfig
from repro.dynamic import MutationBatch
from repro.graph import chung_lu_powerlaw


def _random_batch(g, rng, k=16):
    """k random edge inserts + exactly k deletes of existing edges.

    Balanced batches keep the edge count — and so the CSR buffer shapes —
    constant across epochs: after the first refresh compiles the row-walk
    program at this shape, every later epoch re-dispatches it instead of
    re-tracing."""
    ins = [(int(rng.integers(g.n)), int(rng.integers(g.n)))
           for _ in range(k)]
    dels, pending = set(), {}
    while len(dels) < k:
        v = int(rng.integers(g.n))
        succ = g.successors(v)
        # leave ≥ 1 out-edge so no delete triggers a dangling repair
        # (a repair would append an edge and change the buffer shapes)
        if len(succ) - pending.get(v, 0) > 1:
            d = (v, int(succ[rng.integers(len(succ))]))
            if d not in dels:
                dels.add(d)
                pending[v] = pending.get(v, 0) + 1
    return MutationBatch.edges(insert=ins, delete=sorted(dels))


def main():
    print("Generating a 20k-vertex power-law graph…")
    g = chung_lu_powerlaw(n=20_000, avg_out_deg=10, seed=0)
    cfg = RuntimeConfig(
        runtime=ShardConfig(num_shards=1, seed=7),
        serving=ServingConfig(segments_per_vertex=8, segment_len=4,
                              build_shards=4, max_walks=4096,
                              max_queries=4, max_steps=32))
    rng = np.random.default_rng(42)

    with Gateway.open(g, cfg, replicas=2) as gw:
        print("Building the walk index (epoch 0)…")
        r0 = gw.topk(k=10, epsilon=0.4, delta=0.1).result()
        print(f"  epoch {r0.epoch} top-10: {list(r0.vertices)}")
        assert gw.topk(k=10, epsilon=0.4, delta=0.1).source == "cache"

        for round_ in range(3):
            batch = _random_batch(gw.pool.graph, rng)
            # admit a query, let it start, then mutate underneath it
            h = gw.topk(k=10, epsilon=0.4, delta=0.1)

            t0 = time.perf_counter()
            report = gw.apply_mutations(batch)
            dt = time.perf_counter() - t0
            frac = report.segments_rebuilt / report.total_segments
            print(f"epoch {report.epoch}: {batch.size} mutations → "
                  f"{report.segments_rebuilt}/{report.total_segments} "
                  f"segments rebuilt ({frac:.1%}) in {dt * 1e3:.0f} ms")

            r_old = h.result()               # pinned to its admission epoch
            r_new = gw.topk(k=10, epsilon=0.4, delta=0.1).result()
            print(f"  in-flight query settled on epoch {r_old.epoch}; "
                  f"fresh query on epoch {r_new.epoch} "
                  f"(source={'cache' if r_new is r_old else 'live'})")
            assert r_old.epoch == report.epoch - 1 or r_old.epoch == 0
            assert r_new.epoch == report.epoch

        s = gw.stats()
        print(f"\nGateway after 3 epochs: graph_epoch={s['graph_epoch']} "
              f"orphaned_certs={s['epoch_orphaned']} "
              f"cache_evictions={s['cache']['epoch_evictions']} "
              f"requests={s['requests']}")


if __name__ == "__main__":
    main()
