"""Train a small LM (any assigned architecture, reduced config) on the
synthetic stream with checkpoint/restart — kill it mid-run and relaunch to
see crash recovery.

  PYTHONPATH=src python examples/train_lm.py --arch llama3.2-1b --steps 60
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--partial-sync", type=float, default=1.0)
    args = ap.parse_args()
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", args.arch, "--smoke", "--steps", str(args.steps),
           "--ckpt-dir", f"/tmp/repro_ckpt_{args.arch}",
           "--partial-sync", str(args.partial_sync)]
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
