"""End-to-end driver (the paper's kind: graph analytics serving): run the
DISTRIBUTED FrogWild! engine over an 8-shard mesh, with partial
synchronization, byte accounting and the GraphLab-PR baseline comparison.

  PYTHONPATH=src python examples/distributed_topk.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax

from repro import FrogWildService, RuntimeConfig, ShardConfig
from repro.core import normalized_mass_captured, power_iteration
from repro.engine import distributed_power_iteration
from repro.engine.baseline import build_pull_graph
from repro.engine.netcost import frogwild_bytes_measured, pagerank_bytes_model
from repro.graph import chung_lu_powerlaw


def main():
    mesh = jax.make_mesh((8,), ("vertex",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    print("Generating a 64k-vertex power-law graph…")
    g = chung_lu_powerlaw(n=65_536, avg_out_deg=14, seed=0)

    print("Ground truth via the distributed GraphLab-PR baseline (60 it)…")
    pg = build_pull_graph(g, 8)
    t0 = time.time()
    pi = distributed_power_iteration(pg, mesh, num_iters=60)
    print(f"  {time.time() - t0:.1f}s; bytes/2-iter would be "
          f"{pagerank_bytes_model(g.n, 2, 8).total / 1e6:.1f} MB")

    # The service opened with a mesh dispatches pagerank() through the
    # distributed engine (the per-shard CSR blocks are built and cached
    # inside the service).
    config = RuntimeConfig(num_frogs=800_000, num_steps=4,
                           runtime=ShardConfig(num_shards=8))
    svc = FrogWildService.open(g, config, mesh=mesh)
    for p_s in (1.0, 0.4):
        t0 = time.time()
        res = svc.pagerank(seed=0,
                           config=dataclasses.replace(config, p_s=p_s))
        dt = time.time() - t0
        rep = frogwild_bytes_measured(res.sent_per_step,
                                      res.sync_msgs_per_step)
        m = float(normalized_mass_captured(res.pi_hat, pi, 100))
        print(f"FrogWild p_s={p_s}: {dt:.1f}s  mass@100={m:.4f}  "
              f"wire={rep.total / 1e6:.2f} MB  overflow={res.overflow}")


if __name__ == "__main__":
    main()
