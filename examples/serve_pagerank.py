"""Serve PageRank queries through the FrogWildService facade.

Opens a :class:`~repro.service.FrogWildService` over a generated power-law
graph — the service owns the walk-index lifecycle (build, checkpoint
round-trip, reuse) and the continuous-batching scheduler — then submits a
batch of concurrent global top-k and personalized-PageRank queries as
:class:`~repro.service.QueryHandle` futures and drives them to completion,
printing each handle's anytime ``epsilon_bound`` refinement along the way.

  PYTHONPATH=src python examples/serve_pagerank.py

Old flags still accepted: ``--shards S`` serves from the slab as ``S``
per-shard blocks with **no reassembly** (one ``shard_map`` on a mesh with
≥ S devices, a host loop of the same per-shard program otherwise),
``--slo-ms`` attaches a latency SLO to every request so the deadline-aware
(and now queue-depth-aware) admission controller is exercised, and
``--budget-walks`` gives every query a walk budget beyond its Theorem-1
plan, demonstrating early termination once the requested (ε, δ) bound is
certified.

New (PR 7): ``--replicas N`` serves the same workload through the
**gateway tier** instead — N service replicas over ONE shared walk-index
slab, routed by EDF-charged queue depth, fronted by the (ε, δ)-aware
result cache (``--no-cache`` disables it) with in-flight dedup. Repeating
the stream shows dominated certificates answering with zero new walks.
``--port P`` additionally mounts the stdlib HTTP front-end (``/pagerank``
``/topk`` ``/ppr`` ``/healthz`` ``/metrics``; 0 = ephemeral port) and
curls it once:

  PYTHONPATH=src python examples/serve_pagerank.py --replicas 2 --port 0
"""
import argparse
import json
import tempfile
import time
import urllib.request

import jax
import numpy as np

from repro import (FrogWildService, Gateway, RuntimeConfig, ServingConfig,
                   ShardConfig)
from repro.core import normalized_mass_captured, power_iteration
from repro.gateway import serve_http
from repro.graph import chung_lu_powerlaw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--segments", type=int, default=16, help="R per vertex")
    ap.add_argument("--segment-len", type=int, default=4, help="L steps")
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--shards", type=int, default=0,
                    help="serve from S per-shard slab blocks (0 = dense)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="attach this latency SLO to every request")
    ap.add_argument("--budget-walks", type=int, default=0,
                    help="per-query walk budget (> plan ⇒ anytime early "
                         "termination once the ε bound is certified)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve through the gateway tier over N replicas "
                         "sharing one walk-index slab (0 = direct service)")
    ap.add_argument("--cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="the gateway's (ε, δ)-aware result cache "
                         "(--no-cache disables; gateway mode only)")
    ap.add_argument("--port", type=int, default=None,
                    help="also mount the HTTP front-end on this port "
                         "(0 = ephemeral; gateway mode only)")
    args = ap.parse_args()

    print(f"Generating a {args.n}-vertex power-law graph (θ=2.2)…")
    g = chung_lu_powerlaw(n=args.n, avg_out_deg=12, seed=0)
    print(f"  n={g.n} edges={g.nnz}")

    with tempfile.TemporaryDirectory() as ckpt:
        config = RuntimeConfig(
            runtime=ShardConfig(num_shards=max(args.shards, 1)),
            serving=ServingConfig(
                segments_per_vertex=args.segments,
                segment_len=args.segment_len,
                build_shards=8, max_walks=8192, max_queries=8,
                max_steps=32, checkpoint_dir=ckpt,
            ),
        )
        if args.replicas:
            _serve_via_gateway(g, config, args)
            return

        svc = FrogWildService.open(g, config)

        t0 = time.perf_counter()
        index = svc.ensure_index()
        print(f"Walk index: {g.n}×{args.segments} length-{args.segment_len} "
              f"segments in {time.perf_counter() - t0:.2f}s "
              f"(persisted via checkpoint/ under {ckpt})")
        if args.shards:
            print(f"Sharded slab: {index.num_shards} × "
                  f"[{index.shard_size}, {index.segments_per_vertex}] blocks "
                  f"({index.blocks[0].nbytes / 1e6:.2f} MB/device, "
                  f"never reassembled); dispatch: "
                  f"{'shard_map mesh' if svc.scheduler.runtime.is_mesh else 'host loop'}")

        hubs = np.asarray(g.out_deg).argsort()[-3:]
        slo = (args.slo_ms / 1e3) or None
        budget = args.budget_walks or None
        handles = []
        for i in range(args.queries):
            if i % 3 == 2:
                h = svc.ppr(int(hubs[i % 3]), k=10, epsilon=0.3, slo_s=slo,
                            num_walks=budget, allow_downgrade=True)
            else:
                h = svc.topk(k=10, epsilon=0.3, slo_s=slo,
                             num_walks=budget, allow_downgrade=True)
            handles.append(h)
            if not h.admitted:
                print(f"  q{h.rid:02d} REJECTED at admission: "
                      f"{h.decision.reason}")
            elif h.decision.downgraded:
                print(f"  q{h.rid:02d} downgraded to "
                      f"{h.decision.num_walks} walks (ε bound "
                      f"{h.decision.plan.epsilon_bound:.3f}) to fit "
                      f"{args.slo_ms:.0f}ms SLO")

        # Watch one future refine: its epsilon_bound tightens every wave.
        probe = next((h for h in handles if h.admitted), None)
        t0 = time.perf_counter()
        if probe is not None:
            while not probe.poll():
                p = probe.partial()
                print(f"  q{probe.rid:02d} partial: walks={p.walks_done} "
                      f"ε_bound={p.epsilon_bound:.3f}")
        results = svc.drain()
        dt = time.perf_counter() - t0
        print(f"Served {len(results)} queries in {dt:.2f}s "
              f"({len(results) / dt:.1f} queries/s; "
              f"{len(svc.scheduler.rejected)} rejected at admission)")

        print("Exact PageRank (50 power iterations) for reference…")
        pi = power_iteration(g, num_iters=50)
        for r in sorted(results, key=lambda r: r.rid):
            early = " early-stop" if r.early_stopped else ""
            if r.kind == "topk":
                est = np.zeros(g.n)
                est[r.vertices] = r.scores
                mass = float(normalized_mass_captured(
                    jax.numpy.asarray(est), pi, 10))
                print(f"  q{r.rid:02d} topk  waves={r.waves} "
                      f"walks={r.num_walks} ε_bound={r.epsilon_bound:.3f}"
                      f"{early} mass@10={mass:.3f} "
                      f"top5={list(map(int, r.vertices[:5]))}")
            else:
                print(f"  q{r.rid:02d} ppr   waves={r.waves} "
                      f"walks={r.num_walks} ε_bound={r.epsilon_bound:.3f}"
                      f"{early} source→top5="
                      f"{list(map(int, r.vertices[:5]))} "
                      f"scores={np.round(r.scores[:5], 4).tolist()}")


def _serve_via_gateway(g, config, args):
    """The gateway tier: replicas sharing one slab, dominance-checked
    cache, in-flight dedup, metrics, and (optionally) the HTTP front-end.

    Uses ε = 0.4 — feasible at max_steps=32, so finished certificates
    (≈ 0.392) dominate repeat requests; tighter targets are honestly
    clamped wider by the Theorem-1 planner and would never re-hit.
    """
    eps = 0.4
    hubs = np.asarray(g.out_deg).argsort()[-3:]
    t0 = time.perf_counter()
    with Gateway.open(g, config, replicas=args.replicas,
                      cache=args.cache) as gw:
        print(f"Gateway: {args.replicas} replicas over one "
              f"{g.n}×{args.segments} slab, cache="
              f"{'on' if args.cache else 'off'} "
              f"(opened in {time.perf_counter() - t0:.2f}s)")

        def stream():
            return [gw.ppr(int(hubs[i % 3]), k=10, epsilon=eps)
                    if i % 3 == 2 else gw.topk(k=10, epsilon=eps)
                    for i in range(args.queries)]

        t0 = time.perf_counter()
        first = stream()                    # live + in-flight dedup joins
        for h in first:
            h.result()
        dt1 = time.perf_counter() - t0
        t0 = time.perf_counter()
        second = stream()                   # dominated certificates: free
        for h in second:
            h.result()
        dt2 = time.perf_counter() - t0
        by = lambda hs, src: sum(h.source == src for h in hs)  # noqa: E731
        print(f"  pass 1: {len(first)} queries in {dt1:.2f}s "
              f"(live={by(first, 'live')} joined={by(first, 'joined')} "
              f"cache={by(first, 'cache')})")
        print(f"  pass 2: {len(second)} queries in {dt2 * 1e3:.1f}ms "
              f"(cache={by(second, 'cache')} — zero new walks)")
        s = gw.stats()
        print(f"  tier: qps={s['qps']} p50={s['p50_ms']}ms "
              f"p99={s['p99_ms']}ms hit_rate={s['hit_rate']:.2f} "
              f"join_rate={s['join_rate']:.2f}")
        for r in s["replicas"]:
            print(f"  replica {r['replica']}: waves={r['waves_run']} "
                  f"walks={r['walks_executed']} "
                  f"occupancy={r['wave_occupancy']:.2f}")

        if args.port is not None:
            with serve_http(gw, port=args.port) as srv:
                print(f"  HTTP front-end at {srv.url} "
                      f"(/pagerank /topk /ppr /healthz /metrics)")
                for path in ("/healthz", f"/topk?k=5&epsilon={eps}"):
                    with urllib.request.urlopen(srv.url + path) as resp:
                        body = json.loads(resp.read())
                    print(f"  GET {path} -> {resp.status} "
                          f"{json.dumps(body)[:100]}")


if __name__ == "__main__":
    main()
