"""Serve PageRank queries from a precomputed walk index.

Builds the offline walk-segment index on a generated power-law graph, then
serves a batch of concurrent global top-k and personalized-PageRank queries
through the continuous-batching :class:`~repro.query.QueryScheduler` — the
FrogWild machinery as an online service instead of a batch job.

  PYTHONPATH=src python examples/serve_pagerank.py

``--shards S`` serves from the slab as ``S`` per-shard blocks with **no
reassembly** (``distributed/runtime.py`` dispatch: one ``shard_map`` on a
mesh with ≥ S devices, a host loop of the same per-shard program
otherwise), and ``--slo-ms`` attaches a latency SLO to every request so the
deadline-aware admission controller is exercised (watch for rejected /
downgraded decisions once a wave time has been measured).
"""
import argparse
import tempfile
import time

import jax
import numpy as np

from repro.core import normalized_mass_captured, power_iteration
from repro.graph import chung_lu_powerlaw
from repro.query import (QueryRequest, QueryScheduler, WalkIndexConfig,
                         build_walk_index, load_walk_index, save_walk_index,
                         shard_walk_index)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--segments", type=int, default=16, help="R per vertex")
    ap.add_argument("--segment-len", type=int, default=4, help="L steps")
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--shards", type=int, default=0,
                    help="serve from S per-shard slab blocks (0 = dense)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="attach this latency SLO to every request")
    args = ap.parse_args()

    print(f"Generating a {args.n}-vertex power-law graph (θ=2.2)…")
    g = chung_lu_powerlaw(n=args.n, avg_out_deg=12, seed=0)
    print(f"  n={g.n} edges={g.nnz}")

    cfg = WalkIndexConfig(segments_per_vertex=args.segments,
                          segment_len=args.segment_len, num_shards=8)
    t0 = time.perf_counter()
    index = build_walk_index(g, cfg)
    print(f"Walk index: {g.n}×{args.segments} length-{args.segment_len} "
          f"segments in {time.perf_counter() - t0:.2f}s "
          f"({index.endpoints.nbytes / 1e6:.1f} MB slab)")

    with tempfile.TemporaryDirectory() as d:
        save_walk_index(d, index)
        index = load_walk_index(d)          # checkpoint round-trip
        print(f"  persisted + restored via checkpoint/ ({d})")

    if args.shards:
        index = shard_walk_index(index, args.shards)
        print(f"Sharded slab: {args.shards} × "
              f"[{index.shard_size}, {index.segments_per_vertex}] blocks "
              f"({index.blocks[0].nbytes / 1e6:.2f} MB/device, "
              f"never reassembled)")
    sched = QueryScheduler(g, index, max_walks=8192, max_queries=8,
                           max_steps=32)
    if args.shards:
        print(f"  dispatch: "
              f"{'shard_map mesh' if sched.runtime.is_mesh else 'host loop'}")
    hubs = np.asarray(g.out_deg).argsort()[-3:]
    slo = (args.slo_ms / 1e3) or None
    for i in range(args.queries):
        if i % 3 == 2:
            req = QueryRequest(rid=i, kind="ppr", source=int(hubs[i % 3]),
                               k=10, epsilon=0.3, slo_s=slo,
                               allow_downgrade=True)
        else:
            req = QueryRequest(rid=i, kind="topk", k=10, epsilon=0.3,
                               slo_s=slo, allow_downgrade=True)
        decision = sched.submit(req)
        if not decision.admitted:
            print(f"  q{i:02d} REJECTED at admission: {decision.reason}")
        elif decision.downgraded:
            print(f"  q{i:02d} downgraded to {decision.num_walks} walks "
                  f"(ε bound {decision.plan.epsilon_bound:.3f}) to fit "
                  f"{args.slo_ms:.0f}ms SLO")

    t0 = time.perf_counter()
    results = sched.run()
    dt = time.perf_counter() - t0
    print(f"Served {len(results)} queries in {dt:.2f}s "
          f"({len(results) / dt:.1f} queries/s; "
          f"{len(sched.rejected)} rejected at admission)")

    print("Exact PageRank (50 power iterations) for reference…")
    pi = power_iteration(g, num_iters=50)
    for r in results:
        if r.kind == "topk":
            est = np.zeros(g.n)
            est[r.vertices] = r.scores
            mass = float(normalized_mass_captured(
                jax.numpy.asarray(est), pi, 10))
            print(f"  q{r.rid:02d} topk  waves={r.waves} "
                  f"walks={r.num_walks} mass@10={mass:.3f} "
                  f"top5={list(map(int, r.vertices[:5]))}")
        else:
            print(f"  q{r.rid:02d} ppr   waves={r.waves} "
                  f"walks={r.num_walks} source→top5="
                  f"{list(map(int, r.vertices[:5]))} "
                  f"scores={np.round(r.scores[:5], 4).tolist()}")


if __name__ == "__main__":
    main()
