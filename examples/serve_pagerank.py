"""Serve PageRank queries through the FrogWildService facade.

Opens a :class:`~repro.service.FrogWildService` over a generated power-law
graph — the service owns the walk-index lifecycle (build, checkpoint
round-trip, reuse) and the continuous-batching scheduler — then submits a
batch of concurrent global top-k and personalized-PageRank queries as
:class:`~repro.service.QueryHandle` futures and drives them to completion,
printing each handle's anytime ``epsilon_bound`` refinement along the way.

  PYTHONPATH=src python examples/serve_pagerank.py

Old flags still accepted: ``--shards S`` serves from the slab as ``S``
per-shard blocks with **no reassembly** (one ``shard_map`` on a mesh with
≥ S devices, a host loop of the same per-shard program otherwise), and
``--slo-ms`` attaches a latency SLO to every request so the deadline-aware
(and now queue-depth-aware) admission controller is exercised. New:
``--budget-walks`` gives every query a walk budget beyond its Theorem-1
plan, demonstrating early termination once the requested (ε, δ) bound is
certified.
"""
import argparse
import tempfile
import time

import jax
import numpy as np

from repro import FrogWildService, RuntimeConfig, ServingConfig, ShardConfig
from repro.core import normalized_mass_captured, power_iteration
from repro.graph import chung_lu_powerlaw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--segments", type=int, default=16, help="R per vertex")
    ap.add_argument("--segment-len", type=int, default=4, help="L steps")
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--shards", type=int, default=0,
                    help="serve from S per-shard slab blocks (0 = dense)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="attach this latency SLO to every request")
    ap.add_argument("--budget-walks", type=int, default=0,
                    help="per-query walk budget (> plan ⇒ anytime early "
                         "termination once the ε bound is certified)")
    args = ap.parse_args()

    print(f"Generating a {args.n}-vertex power-law graph (θ=2.2)…")
    g = chung_lu_powerlaw(n=args.n, avg_out_deg=12, seed=0)
    print(f"  n={g.n} edges={g.nnz}")

    with tempfile.TemporaryDirectory() as ckpt:
        config = RuntimeConfig(
            runtime=ShardConfig(num_shards=max(args.shards, 1)),
            serving=ServingConfig(
                segments_per_vertex=args.segments,
                segment_len=args.segment_len,
                build_shards=8, max_walks=8192, max_queries=8,
                max_steps=32, checkpoint_dir=ckpt,
            ),
        )
        svc = FrogWildService.open(g, config)

        t0 = time.perf_counter()
        index = svc.ensure_index()
        print(f"Walk index: {g.n}×{args.segments} length-{args.segment_len} "
              f"segments in {time.perf_counter() - t0:.2f}s "
              f"(persisted via checkpoint/ under {ckpt})")
        if args.shards:
            print(f"Sharded slab: {index.num_shards} × "
                  f"[{index.shard_size}, {index.segments_per_vertex}] blocks "
                  f"({index.blocks[0].nbytes / 1e6:.2f} MB/device, "
                  f"never reassembled); dispatch: "
                  f"{'shard_map mesh' if svc.scheduler.runtime.is_mesh else 'host loop'}")

        hubs = np.asarray(g.out_deg).argsort()[-3:]
        slo = (args.slo_ms / 1e3) or None
        budget = args.budget_walks or None
        handles = []
        for i in range(args.queries):
            if i % 3 == 2:
                h = svc.ppr(int(hubs[i % 3]), k=10, epsilon=0.3, slo_s=slo,
                            num_walks=budget, allow_downgrade=True)
            else:
                h = svc.topk(k=10, epsilon=0.3, slo_s=slo,
                             num_walks=budget, allow_downgrade=True)
            handles.append(h)
            if not h.admitted:
                print(f"  q{h.rid:02d} REJECTED at admission: "
                      f"{h.decision.reason}")
            elif h.decision.downgraded:
                print(f"  q{h.rid:02d} downgraded to "
                      f"{h.decision.num_walks} walks (ε bound "
                      f"{h.decision.plan.epsilon_bound:.3f}) to fit "
                      f"{args.slo_ms:.0f}ms SLO")

        # Watch one future refine: its epsilon_bound tightens every wave.
        probe = next((h for h in handles if h.admitted), None)
        t0 = time.perf_counter()
        if probe is not None:
            while not probe.poll():
                p = probe.partial()
                print(f"  q{probe.rid:02d} partial: walks={p.walks_done} "
                      f"ε_bound={p.epsilon_bound:.3f}")
        results = svc.drain()
        dt = time.perf_counter() - t0
        print(f"Served {len(results)} queries in {dt:.2f}s "
              f"({len(results) / dt:.1f} queries/s; "
              f"{len(svc.scheduler.rejected)} rejected at admission)")

        print("Exact PageRank (50 power iterations) for reference…")
        pi = power_iteration(g, num_iters=50)
        for r in sorted(results, key=lambda r: r.rid):
            early = " early-stop" if r.early_stopped else ""
            if r.kind == "topk":
                est = np.zeros(g.n)
                est[r.vertices] = r.scores
                mass = float(normalized_mass_captured(
                    jax.numpy.asarray(est), pi, 10))
                print(f"  q{r.rid:02d} topk  waves={r.waves} "
                      f"walks={r.num_walks} ε_bound={r.epsilon_bound:.3f}"
                      f"{early} mass@10={mass:.3f} "
                      f"top5={list(map(int, r.vertices[:5]))}")
            else:
                print(f"  q{r.rid:02d} ppr   waves={r.waves} "
                      f"walks={r.num_walks} ε_bound={r.epsilon_bound:.3f}"
                      f"{early} source→top5="
                      f"{list(map(int, r.vertices[:5]))} "
                      f"scores={np.round(r.scores[:5], 4).tolist()}")


if __name__ == "__main__":
    main()
